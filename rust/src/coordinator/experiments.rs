//! Experiment runners regenerating the paper's evaluation (§5):
//!
//! * **Fig 8** — partitioned model step time (ms), per model × platform ×
//!   method, 16 devices.
//! * **Fig 9** — auto-sharding search time (s), same grid.
//! * **Fig 10** — T2B sequence-length scaling on a 3-D Batch×Seq×Model
//!   mesh: step time and search time vs sequence length/devices.
//! * **Ablations** — conflict-resolution actions, action-space pruning
//!   threshold, and parameter-group mirroring (the DESIGN.md §7 switches).
//!
//! Absolute milliseconds come from the shared analytic cost model (this
//! testbed has no accelerators); the *shape* of the comparison — who
//! wins, where OOMs appear, how search time scales — is the
//! reproduction target (DESIGN.md §3).

use crate::api::{CompiledModel, Solution};
use crate::baselines::Method;
use crate::cost::symbolic::SymbolicEvaluator;
use crate::cost::CostModel;
use crate::ir::Func;
use crate::mesh::{HardwareKind, Mesh, Topology};
use crate::models::{gns, itx, transformer, unet, ModelKind};
use crate::search::{Action, IncrementalEvaluator};
use crate::sharding::{partition, ShardingSpec};
use crate::util::json::Json;
use crate::util::Rng;

/// How big the experiment models are.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BenchScale {
    /// Interpreter-sized (seconds; used by tests).
    Tiny,
    /// Structure-preserving mid-size (default for `cargo bench`).
    Bench,
    /// The paper's full-size IR (minutes).
    Paper,
}

impl BenchScale {
    pub fn budget(self) -> usize {
        match self {
            BenchScale::Tiny => 60,
            BenchScale::Bench => 150,
            BenchScale::Paper => 300,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BenchScale::Tiny => "tiny",
            BenchScale::Bench => "bench",
            BenchScale::Paper => "paper",
        }
    }
}

/// Which experiment to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Experiment {
    Fig8,
    Fig9,
    Fig10,
    Ablations,
    /// Differential-validation sweep: SPMD simulator vs. interpreter
    /// oracle over the scaled zoo (see [`run_differential_suite`]).
    Differential,
    /// Pipeline-stage sweep: staged execution vs. the interpreter oracle
    /// plus schedule-pricing agreement (see [`run_pipeline_suite`]).
    Pipeline,
    /// Search-speed campaign: evaluator throughput, flat and joint MCTS
    /// legacy-vs-optimized comparisons, zoo joint wall times (see
    /// [`run_search_speed`]); `BENCH_search_speed.json` is its committed
    /// baseline.
    SearchSpeed,
    /// Service load generator: a repeated-request workload against an
    /// in-process service, publishing requests/sec and p50/p99 latency
    /// for the cold (search) and warm (solution-cache hit) phases (see
    /// [`run_service_load`]); `BENCH_service_load.json` is its committed
    /// baseline.
    ServiceLoad,
    /// MoE expert-parallel smoke: on meshes with a dedicated expert
    /// axis, compare the best expert(×data)-sharded plan (routed
    /// `all_to_all` at dispatch/combine) against the best pure-data
    /// plan, pin symbolic pricing to the materialize-and-evaluate
    /// oracle, and differentially validate the winner (see
    /// [`run_moe_suite`]).
    Moe,
    /// Topology sweep: the same model priced on a flat NVLink profile vs
    /// a two-island profile must pick *different* winning plans, with the
    /// island-aware winner cheaper under island pricing, and symbolic,
    /// incremental, and oracle pricing agreeing on every plan (see
    /// [`run_topology_suite`]).
    Topology,
}

impl std::str::FromStr for Experiment {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "fig8" => Ok(Experiment::Fig8),
            "fig9" => Ok(Experiment::Fig9),
            "fig10" => Ok(Experiment::Fig10),
            "ablations" => Ok(Experiment::Ablations),
            "differential" | "diff" => Ok(Experiment::Differential),
            "pipeline" | "stages" => Ok(Experiment::Pipeline),
            "search-speed" | "search_speed" => Ok(Experiment::SearchSpeed),
            "service-load" | "service_load" => Ok(Experiment::ServiceLoad),
            "moe" => Ok(Experiment::Moe),
            "topology" | "topo" => Ok(Experiment::Topology),
            other => Err(format!(
                "unknown experiment '{other}' (fig8|fig9|fig10|ablations|differential|\
                 pipeline|search-speed|service-load|moe|topology)"
            )),
        }
    }
}

/// Build a model at the requested scale (structure-preserving shrink for
/// `Bench`).
pub fn build_model(kind: ModelKind, scale: BenchScale) -> Func {
    match scale {
        BenchScale::Tiny => kind.build_scaled(),
        BenchScale::Paper => kind.build_paper(),
        BenchScale::Bench => match kind {
            ModelKind::T2B => transformer::training_step(&transformer::TransformerConfig {
                d_model: 512,
                layers: 4,
                hidden: 2048,
                heads: 8,
                key_size: 64,
                vocab: 8192,
                batch: 16,
                seq: 512,
                training: true,
            }),
            ModelKind::T7B => transformer::training_step(&transformer::TransformerConfig {
                d_model: 768,
                layers: 6,
                hidden: 3072,
                heads: 12,
                key_size: 64,
                vocab: 8192,
                batch: 16,
                seq: 512,
                training: true,
            }),
            ModelKind::Gns => gns::training_step(&gns::GnsConfig {
                n_nodes: 512,
                n_edges: 2048,
                latent: 256,
                hidden: 128,
                steps: 8,
                training: true,
            }),
            ModelKind::UNet => unet::training_step(&unet::UNetConfig {
                batch: 8,
                size: 32,
                in_channels: 4,
                base_channels: 64,
                channel_mults: vec![1, 2],
                down_blocks_per_level: 2,
                up_blocks_per_level: 2,
                attn_heads: 8,
                training: true,
            }),
            ModelKind::Itx => itx::inference_step(&itx::ItxConfig {
                d_model: 256,
                layers: 6,
                hidden: 1024,
                heads: 8,
                vocab: 8192,
                batch: 8,
                cache_len: 512,
            }),
            other => other.build_scaled(),
        },
    }
}

/// One grid point result.
#[derive(Clone, Debug)]
pub struct GridRow {
    pub model: ModelKind,
    pub hardware: HardwareKind,
    pub method: Method,
    pub step_ms: f64,
    pub search_s: f64,
    pub oom: bool,
    pub relative: f64,
    pub peak_gib: f64,
}

impl GridRow {
    fn from(model: ModelKind, hardware: HardwareKind, method: Method, s: &Solution) -> GridRow {
        GridRow {
            model,
            hardware,
            method,
            step_ms: s.cost.runtime_s * 1e3,
            search_s: s.search_time_s,
            oom: s.oom,
            relative: s.relative,
            peak_gib: s.cost.peak_bytes as f64 / (1u64 << 30) as f64,
        }
    }

    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::s(self.model.name())),
            ("hardware", Json::s(self.hardware.name())),
            ("method", Json::s(self.method.name())),
            ("step_ms", Json::n(self.step_ms)),
            ("search_s", Json::n(self.search_s)),
            ("oom", Json::Bool(self.oom)),
            ("relative", Json::n(self.relative)),
            ("peak_gib", Json::n(self.peak_gib)),
        ])
    }
}

/// The Fig 8/9 grid: models × platforms × methods on a 16-device 2-D mesh.
///
/// Each model is compiled **once** (one NDA, one cached action space per
/// mesh) and every platform × method point runs as a session against the
/// shared [`CompiledModel`].
pub fn run_grid(
    scale: BenchScale,
    models: &[ModelKind],
    hardware: &[HardwareKind],
    methods: &[Method],
) -> Vec<GridRow> {
    let mut rows = Vec::new();
    for &mk in models {
        let compiled = CompiledModel::compile_annotated(
            build_model(mk, scale),
            Some(mk),
            scale == BenchScale::Paper,
        )
        .expect("zoo model compiles");
        let mesh = Mesh::grid(&[("data", 4), ("model", 4)]);
        for &hw in hardware {
            for &method in methods {
                let sol = compiled
                    .partition(&mesh)
                    .method(method)
                    .topology(Topology::from_kind(hw))
                    .budget(scale.budget())
                    .seed(17)
                    .run()
                    .expect("grid point runs");
                rows.push(GridRow::from(mk, hw, method, &sol));
            }
        }
    }
    rows
}

/// Fig 10: T2B sequence scaling on a 3-D mesh (Batch × Seq × Model).
/// Returns `(seq_len, mesh description, rows)` triples.
pub fn run_seq_scaling(scale: BenchScale) -> Vec<(i64, String, Vec<GridRow>)> {
    // (seq, mesh) pairs; paper goes to 32k over 2x32x2 = 128 devices.
    let points: Vec<(i64, Vec<(&str, usize)>)> = match scale {
        BenchScale::Tiny => vec![
            (256, vec![("batch", 2), ("seq", 2), ("model", 2)]),
            (512, vec![("batch", 2), ("seq", 4), ("model", 2)]),
        ],
        BenchScale::Bench => vec![
            (1024, vec![("batch", 2), ("seq", 4), ("model", 2)]),
            (4096, vec![("batch", 2), ("seq", 8), ("model", 2)]),
            (8192, vec![("batch", 2), ("seq", 16), ("model", 2)]),
        ],
        BenchScale::Paper => vec![
            (2048, vec![("batch", 2), ("seq", 8), ("model", 2)]),
            (8192, vec![("batch", 2), ("seq", 16), ("model", 2)]),
            (16384, vec![("batch", 2), ("seq", 32), ("model", 2)]),
            (32768, vec![("batch", 2), ("seq", 32), ("model", 2)]),
        ],
    };
    let methods = [Method::Manual, Method::Alpa, Method::AutoMap, Method::Toast];
    let mut out = Vec::new();
    for (seq, axes) in points {
        // T2B dims at Bench scale shrink everything but the sequence.
        let cfg = match scale {
            BenchScale::Paper => transformer::TransformerConfig {
                seq,
                batch: 4,
                ..transformer::TransformerConfig::t2b()
            },
            _ => transformer::TransformerConfig {
                d_model: 256,
                layers: 2,
                hidden: 1024,
                heads: 8,
                key_size: 32,
                vocab: 4096,
                batch: 4,
                seq,
                training: true,
            },
        };
        let compiled = CompiledModel::compile_annotated(
            transformer::training_step(&cfg),
            Some(ModelKind::T2B),
            false,
        )
        .expect("T2B variant compiles");
        let mesh = Mesh::grid(&axes);
        let mut rows = Vec::new();
        for method in methods {
            let sol = compiled
                .partition(&mesh)
                .method(method)
                .topology(Topology::from_kind(HardwareKind::A100))
                .budget(scale.budget())
                .seed(29)
                .run()
                .expect("scaling point runs");
            rows.push(GridRow::from(ModelKind::T2B, HardwareKind::A100, method, &sol));
        }
        out.push((seq, mesh.describe(), rows));
    }
    out
}

/// Search state-evaluation throughput of the three evaluators over the
/// same state set (see [`measure_eval_throughput`]).
#[derive(Clone, Debug)]
pub struct EvalThroughput {
    /// States priced per second by materialize-partition-evaluate (the
    /// validation oracle — the seed implementation's hot path).
    pub oracle_evals_per_s: f64,
    /// States priced per second by the full-pass symbolic evaluator.
    pub symbolic_evals_per_s: f64,
    /// States priced per second by the incremental engine walking the
    /// trajectory with its delta API (the search's actual hot path).
    pub incremental_evals_per_s: f64,
}

impl EvalThroughput {
    pub fn symbolic_speedup(&self) -> f64 {
        self.symbolic_evals_per_s / self.oracle_evals_per_s.max(1e-12)
    }

    pub fn incremental_speedup(&self) -> f64 {
        self.incremental_evals_per_s / self.oracle_evals_per_s.max(1e-12)
    }

    /// One row per evaluator, ready for the perf probe / reports.
    pub fn format(&self) -> String {
        format!(
            "evaluator throughput (evals/sec):\n  \
             materialize-partition-evaluate {:>12.1}  (1.0x oracle)\n  \
             symbolic full pass             {:>12.1}  ({:.1}x)\n  \
             incremental engine             {:>12.1}  ({:.1}x)",
            self.oracle_evals_per_s,
            self.symbolic_evals_per_s,
            self.symbolic_speedup(),
            self.incremental_evals_per_s,
            self.incremental_speedup(),
        )
    }
}

/// Measure state-evaluation throughput of the materialized oracle, the
/// symbolic evaluator, and the incremental engine over an identical
/// trajectory of states: a deterministic greedy walk applying the first
/// still-legal action, up to `depth` actions. Each evaluator prices every
/// prefix state `iters` times.
pub fn measure_eval_throughput(
    func: &Func,
    mesh: &Mesh,
    model: &CostModel,
    actions: &[Action],
    depth: usize,
    iters: usize,
) -> EvalThroughput {
    use std::time::Instant;
    // Deterministic greedy walk; all three evaluators price the
    // identical, valid state set (every prefix spec partitions).
    let (walk, specs) = greedy_action_walk(func, mesh, actions, depth);
    let n_states = specs.len() * iters;

    let base = {
        let (local, _) = partition(func, &ShardingSpec::unsharded(func), mesh)
            .expect("identity partition");
        model.evaluate(&local, mesh)
    };

    // Oracle: partition + evaluate per state.
    let t0 = Instant::now();
    for _ in 0..iters {
        for s in &specs {
            let (local, _) = partition(func, s, mesh).expect("walk spec partitions");
            std::hint::black_box(model.relative(&model.evaluate(&local, mesh), &base));
        }
    }
    let oracle_evals_per_s = n_states as f64 / t0.elapsed().as_secs_f64().max(1e-9);

    // Symbolic full pass.
    let sym = SymbolicEvaluator::new(func, mesh, model);
    let t0 = Instant::now();
    for _ in 0..iters {
        for s in &specs {
            std::hint::black_box(sym.relative(s, &base));
        }
    }
    let symbolic_evals_per_s = n_states as f64 / t0.elapsed().as_secs_f64().max(1e-9);

    // Incremental engine: walk the trajectory like the search does. Op
    // rules depend only on `func`, so it reuses the symbolic
    // evaluator's vector instead of deriving its own.
    let mut eng =
        IncrementalEvaluator::with_shared_rules(func, mesh, model, base, sym.shared_rules())
            .expect("logical module");
    let t0 = Instant::now();
    for _ in 0..iters {
        eng.reset();
        std::hint::black_box(eng.relative());
        for &ai in &walk {
            eng.apply(&actions[ai].assignment, actions[ai].axis).expect("walk action applies");
            std::hint::black_box(eng.relative());
        }
    }
    let incremental_evals_per_s = n_states as f64 / t0.elapsed().as_secs_f64().max(1e-9);

    EvalThroughput { oracle_evals_per_s, symbolic_evals_per_s, incremental_evals_per_s }
}

/// One legacy-vs-optimized search comparison, same model / action space /
/// seed / eval budget on both sides. "Legacy" pins every PR-6 lever off
/// (action-id state keys, eager per-visit evaluation, no pruning);
/// "optimized" is the default configuration.
#[derive(Clone, Debug)]
pub struct SearchComparison {
    pub legacy_nodes: usize,
    pub legacy_evals: usize,
    pub legacy_wall_s: f64,
    /// Best relative cost the legacy search found.
    pub legacy_best: f64,
    pub opt_nodes: usize,
    pub opt_evals: usize,
    pub opt_wall_s: f64,
    pub opt_best: f64,
}

impl SearchComparison {
    pub fn legacy_nodes_per_s(&self) -> f64 {
        self.legacy_nodes as f64 / self.legacy_wall_s.max(1e-9)
    }

    pub fn opt_nodes_per_s(&self) -> f64 {
        self.opt_nodes as f64 / self.opt_wall_s.max(1e-9)
    }

    /// Effective nodes/sec ratio, the acceptance-gated speedup.
    pub fn speedup(&self) -> f64 {
        self.opt_nodes_per_s() / self.legacy_nodes_per_s().max(1e-12)
    }

    /// Same-or-better best cost (small epsilon for float noise).
    pub fn cost_parity(&self) -> bool {
        self.opt_best <= self.legacy_best + 1e-6
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("legacy_nodes", Json::n(self.legacy_nodes as f64)),
            ("legacy_evals", Json::n(self.legacy_evals as f64)),
            ("legacy_wall_s", Json::n(self.legacy_wall_s)),
            ("legacy_best", Json::n(self.legacy_best)),
            ("legacy_nodes_per_s", Json::n(self.legacy_nodes_per_s())),
            ("opt_nodes", Json::n(self.opt_nodes as f64)),
            ("opt_evals", Json::n(self.opt_evals as f64)),
            ("opt_wall_s", Json::n(self.opt_wall_s)),
            ("opt_best", Json::n(self.opt_best)),
            ("opt_nodes_per_s", Json::n(self.opt_nodes_per_s())),
            ("speedup", Json::n(self.speedup())),
        ])
    }
}

/// The search-speed report `bench --experiment search-speed` produces and
/// `BENCH_search_speed.json` commits.
#[derive(Clone, Debug)]
pub struct SearchSpeedReport {
    pub scale: BenchScale,
    /// Set only on hand-authored baselines written without a local
    /// toolchain: absolute numbers are estimates, and the CI check
    /// downgrades the ±25% band to a warning until a measured baseline
    /// replaces them.
    pub provisional: bool,
    /// Per-model evaluator throughput (oracle / symbolic / incremental).
    pub eval_throughput: Vec<(ModelKind, EvalThroughput)>,
    /// Flat MCTS on the transformer (informational).
    pub flat: SearchComparison,
    /// Joint (stages × sharding) on the transformer — the gated
    /// comparison: ≥1.3× effective nodes/sec at same-or-better cost.
    pub joint: SearchComparison,
    /// `(model, wall seconds, best relative)` of the optimized joint
    /// search across the zoo.
    pub zoo_joint: Vec<(ModelKind, f64, f64)>,
}

impl SearchSpeedReport {
    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::s("toast.bench.search_speed/v1")),
            ("scale", Json::s(self.scale.name())),
            ("provisional", Json::Bool(self.provisional)),
            (
                "eval_throughput",
                Json::Arr(
                    self.eval_throughput
                        .iter()
                        .map(|(mk, tp)| {
                            Json::obj(vec![
                                ("model", Json::s(mk.name())),
                                ("oracle_evals_per_s", Json::n(tp.oracle_evals_per_s)),
                                ("symbolic_evals_per_s", Json::n(tp.symbolic_evals_per_s)),
                                (
                                    "incremental_evals_per_s",
                                    Json::n(tp.incremental_evals_per_s),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("flat", self.flat.json()),
            ("joint", self.joint.json()),
            (
                "zoo_joint",
                Json::Arr(
                    self.zoo_joint
                        .iter()
                        .map(|(mk, wall, rel)| {
                            Json::obj(vec![
                                ("model", Json::s(mk.name())),
                                ("wall_s", Json::n(*wall)),
                                ("relative", Json::n(*rel)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Run the search-speed campaign: evaluator throughput over the zoo,
/// flat and joint legacy-vs-optimized comparisons on the transformer
/// (identical seed and eval budget on both sides), and optimized
/// joint-search wall time across the zoo.
pub fn run_search_speed(scale: BenchScale) -> SearchSpeedReport {
    use crate::pipeline::{joint_search, JointSearchConfig};
    use crate::search::{
        build_actions, build_stage_actions, search, ActionSpaceConfig, SearchConfig,
        StageActionConfig,
    };
    use std::time::Instant;

    let model = CostModel::new(Topology::from_kind(HardwareKind::A100));
    let mesh = match scale {
        BenchScale::Tiny => Mesh::grid(&[("data", 2), ("model", 2)]),
        _ => Mesh::grid(&[("data", 4), ("model", 4)]),
    };
    let zoo: Vec<ModelKind> = match scale {
        BenchScale::Tiny => vec![ModelKind::Mlp],
        _ => vec![ModelKind::T2B, ModelKind::Gns, ModelKind::Itx],
    };
    let iters = if scale == BenchScale::Tiny { 2 } else { 3 };
    let space = ActionSpaceConfig { min_color_dims: 1, ..Default::default() };

    let mut eval_throughput = Vec::new();
    for &mk in &zoo {
        let func = build_model(mk, scale);
        let nda = crate::nda::Nda::analyze(&func);
        let actions = build_actions(&func, &nda, &mesh, &space);
        let tp = measure_eval_throughput(&func, &mesh, &model, &actions, 4, iters);
        eval_throughput.push((mk, tp));
    }

    // Flat MCTS on the transformer: action-id keys + eager rollouts vs.
    // signature keys + batched leaves. Single worker so both sides pay
    // identical thread overhead and the comparison is reproducible.
    let t2b = build_model(ModelKind::T2B, scale);
    let nda = crate::nda::Nda::analyze(&t2b);
    let actions = build_actions(&t2b, &nda, &mesh, &space);
    let budget = scale.budget() * 2;
    let leg = search(
        &t2b,
        &mesh,
        &model,
        &actions,
        &SearchConfig {
            budget,
            seed: 17,
            threads: 1,
            transpositions: false,
            batch_leaves: 0,
            ..Default::default()
        },
    );
    let opt = search(
        &t2b,
        &mesh,
        &model,
        &actions,
        &SearchConfig { budget, seed: 17, threads: 1, ..Default::default() },
    );
    let flat = SearchComparison {
        legacy_nodes: leg.nodes,
        legacy_evals: leg.evals,
        legacy_wall_s: leg.wall.as_secs_f64(),
        legacy_best: leg.relative,
        opt_nodes: opt.nodes,
        opt_evals: opt.evals,
        opt_wall_s: opt.wall.as_secs_f64(),
        opt_best: opt.relative,
    };

    // Joint (stages × sharding) on the transformer — the gated
    // comparison: transposition keys + leaf rollouts + candidate caching
    // + stage-local pruning vs. the PR-5 configuration.
    let stage_actions = build_stage_actions(&t2b, &nda, &StageActionConfig::default());
    let t0 = Instant::now();
    let jleg = joint_search(
        &t2b,
        &mesh,
        &model,
        &actions,
        &stage_actions,
        &JointSearchConfig {
            budget,
            seed: 17,
            transpositions: false,
            leaf_rollouts: false,
            prune_stage_local: false,
            ..Default::default()
        },
    )
    .expect("legacy joint search runs");
    let jleg_wall = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let jopt = joint_search(
        &t2b,
        &mesh,
        &model,
        &actions,
        &stage_actions,
        &JointSearchConfig { budget, seed: 17, ..Default::default() },
    )
    .expect("joint search runs");
    let jopt_wall = t0.elapsed().as_secs_f64();
    let joint = SearchComparison {
        legacy_nodes: jleg.nodes,
        legacy_evals: jleg.evals,
        legacy_wall_s: jleg_wall,
        legacy_best: jleg.relative,
        opt_nodes: jopt.nodes,
        opt_evals: jopt.evals,
        opt_wall_s: jopt_wall,
        opt_best: jopt.relative,
    };

    // Optimized joint-search wall time across the zoo.
    let mut zoo_joint = Vec::new();
    for &mk in &zoo {
        let func = build_model(mk, scale);
        let nda = crate::nda::Nda::analyze(&func);
        let actions = build_actions(&func, &nda, &mesh, &space);
        let stage_actions = build_stage_actions(
            &func,
            &nda,
            &StageActionConfig { counts: vec![2], ..Default::default() },
        );
        let cfg = JointSearchConfig { budget: scale.budget(), seed: 17, ..Default::default() };
        let t0 = Instant::now();
        let out = joint_search(&func, &mesh, &model, &actions, &stage_actions, &cfg)
            .expect("zoo joint search runs");
        zoo_joint.push((mk, t0.elapsed().as_secs_f64(), out.relative));
    }

    SearchSpeedReport { scale, provisional: false, eval_throughput, flat, joint, zoo_joint }
}

/// Outcome of [`check_search_speed`]: `failures` fail the build,
/// `warnings` are printed (improvements past the band, provisional
/// baselines — things to re-bless deliberately, not regressions).
#[derive(Clone, Debug, Default)]
pub struct BenchCheck {
    pub failures: Vec<String>,
    pub warnings: Vec<String>,
}

/// Relative tolerance band of the baseline comparison (±25%).
pub const BENCH_TOLERANCE: f64 = 0.25;

fn band_check(
    check: &mut BenchCheck,
    name: &str,
    current: f64,
    baseline: Option<f64>,
    higher_is_better: bool,
) {
    let Some(base) = baseline else {
        check.warnings.push(format!("{name}: no baseline entry (skipped)"));
        return;
    };
    if base <= 0.0 || !base.is_finite() || !current.is_finite() {
        check
            .failures
            .push(format!("{name}: unusable values (current {current}, baseline {base})"));
        return;
    }
    let lo = base * (1.0 - BENCH_TOLERANCE);
    let hi = base * (1.0 + BENCH_TOLERANCE);
    let (regressed, improved) =
        if higher_is_better { (current < lo, current > hi) } else { (current > hi, current < lo) };
    if regressed {
        check.failures.push(format!(
            "{name}: {current:.1} regressed past ±{:.0}% of baseline {base:.1}",
            BENCH_TOLERANCE * 100.0
        ));
    } else if improved {
        check.warnings.push(format!(
            "{name}: {current:.1} improved past ±{:.0}% of baseline {base:.1} — re-bless the baseline",
            BENCH_TOLERANCE * 100.0
        ));
    }
}

/// Gate a fresh report: (a) in-run acceptance gates — joint cost parity
/// always, ≥1.3× joint effective nodes/sec when `enforce_speed_gate`
/// (tiny-scale smoke runs relax it: toy models leave the optimizations
/// little to amortize) — and (b) the ±25% band against the committed
/// baseline. A baseline flagged `"provisional": true` (hand-authored
/// estimates) downgrades the absolute band to a warning so the first
/// toolchain-equipped run can re-bless it with measured numbers.
pub fn check_search_speed(
    current: &SearchSpeedReport,
    baseline: Option<&Json>,
    enforce_speed_gate: bool,
) -> BenchCheck {
    let mut check = BenchCheck::default();

    if !current.joint.cost_parity() {
        check.failures.push(format!(
            "joint search cost parity: optimized best {} worse than legacy best {}",
            current.joint.opt_best, current.joint.legacy_best
        ));
    }
    if enforce_speed_gate && current.joint.speedup() < 1.3 {
        check.failures.push(format!(
            "joint search speedup {:.2}x below the 1.3x acceptance gate \
             ({:.1} -> {:.1} nodes/s)",
            current.joint.speedup(),
            current.joint.legacy_nodes_per_s(),
            current.joint.opt_nodes_per_s(),
        ));
    }

    let Some(baseline) = baseline else {
        return check;
    };
    match baseline.get("format").and_then(Json::as_str) {
        Some("toast.bench.search_speed/v1") => {}
        other => {
            check
                .failures
                .push(format!("baseline format {other:?} is not toast.bench.search_speed/v1"));
            return check;
        }
    }
    if baseline.get("provisional").and_then(Json::as_bool) == Some(true) {
        check.warnings.push(
            "baseline is provisional (hand-authored estimates): ±25% band skipped — \
             re-bless it with `toast bench --experiment search-speed --out BENCH_search_speed.json`"
                .to_string(),
        );
        return check;
    }

    let arr_entry = |key: &str, model: &str| -> Option<Json> {
        match baseline.get(key) {
            Some(Json::Arr(rows)) => rows
                .iter()
                .find(|r| r.get("model").and_then(Json::as_str) == Some(model))
                .cloned(),
            _ => None,
        }
    };
    for (mk, tp) in &current.eval_throughput {
        let row = arr_entry("eval_throughput", mk.name());
        let field = |f: &str| row.as_ref().and_then(|r| r.get(f)).and_then(Json::as_f64);
        let name = mk.name();
        band_check(
            &mut check,
            &format!("eval_throughput[{name}].oracle_evals_per_s"),
            tp.oracle_evals_per_s,
            field("oracle_evals_per_s"),
            true,
        );
        band_check(
            &mut check,
            &format!("eval_throughput[{name}].symbolic_evals_per_s"),
            tp.symbolic_evals_per_s,
            field("symbolic_evals_per_s"),
            true,
        );
        band_check(
            &mut check,
            &format!("eval_throughput[{name}].incremental_evals_per_s"),
            tp.incremental_evals_per_s,
            field("incremental_evals_per_s"),
            true,
        );
    }
    for (section, cmp) in [("flat", &current.flat), ("joint", &current.joint)] {
        let base = baseline
            .get(section)
            .and_then(|s| s.get("opt_nodes_per_s"))
            .and_then(Json::as_f64);
        band_check(
            &mut check,
            &format!("{section}.opt_nodes_per_s"),
            cmp.opt_nodes_per_s(),
            base,
            true,
        );
    }
    for (mk, wall, _) in &current.zoo_joint {
        let row = arr_entry("zoo_joint", mk.name());
        let base = row.as_ref().and_then(|r| r.get("wall_s")).and_then(Json::as_f64);
        band_check(
            &mut check,
            &format!("zoo_joint[{}].wall_s", mk.name()),
            *wall,
            base,
            false,
        );
    }
    check
}

/// Render the search-speed report as a table.
pub fn format_search_speed(r: &SearchSpeedReport) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== search speed ({} scale): transpositions + batched leaves + stage pruning ==",
        r.scale.name()
    );
    let _ = writeln!(
        out,
        "{:<10} {:>14} {:>14} {:>14}",
        "model", "oracle e/s", "symbolic e/s", "increm. e/s"
    );
    for (mk, tp) in &r.eval_throughput {
        let _ = writeln!(
            out,
            "{:<10} {:>14.1} {:>14.1} {:>14.1}",
            mk.name(),
            tp.oracle_evals_per_s,
            tp.symbolic_evals_per_s,
            tp.incremental_evals_per_s
        );
    }
    for (title, cmp) in [("flat MCTS (t2b)", &r.flat), ("joint search (t2b)", &r.joint)] {
        let _ = writeln!(
            out,
            "{title}: legacy {:.0} nodes/s ({} evals, best {:.4}) -> optimized {:.0} nodes/s \
             ({} evals, best {:.4}) = {:.2}x{}",
            cmp.legacy_nodes_per_s(),
            cmp.legacy_evals,
            cmp.legacy_best,
            cmp.opt_nodes_per_s(),
            cmp.opt_evals,
            cmp.opt_best,
            cmp.speedup(),
            if cmp.cost_parity() { "" } else { "  [COST REGRESSION]" },
        );
    }
    for (mk, wall, rel) in &r.zoo_joint {
        let _ = writeln!(
            out,
            "zoo joint {:<10} {:>8.2}s wall  best relative {:.4}",
            mk.name(),
            wall,
            rel
        );
    }
    out
}

/// Latency aggregate over one phase of the service-load experiment.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyStats {
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl LatencyStats {
    fn from_samples(mut ms: Vec<f64>) -> LatencyStats {
        if ms.is_empty() {
            return LatencyStats::default();
        }
        ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latency samples"));
        let pct = |p: f64| {
            let idx = ((ms.len() - 1) as f64 * p).round() as usize;
            ms[idx.min(ms.len() - 1)]
        };
        LatencyStats {
            mean_ms: ms.iter().sum::<f64>() / ms.len() as f64,
            p50_ms: pct(0.50),
            p99_ms: pct(0.99),
        }
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("mean_ms", Json::n(self.mean_ms)),
            ("p50_ms", Json::n(self.p50_ms)),
            ("p99_ms", Json::n(self.p99_ms)),
        ])
    }

    fn from_json(j: Option<&Json>) -> LatencyStats {
        let field = |f: &str| j.and_then(|j| j.get(f)).and_then(Json::as_f64).unwrap_or(0.0);
        LatencyStats {
            mean_ms: field("mean_ms"),
            p50_ms: field("p50_ms"),
            p99_ms: field("p99_ms"),
        }
    }
}

/// The service-load report `bench --experiment service-load` produces
/// and `BENCH_service_load.json` commits: the same request set submitted
/// twice against an in-process service, so the cold phase prices the
/// full search path and the warm phase prices a solution-cache hit.
#[derive(Clone, Debug)]
pub struct ServiceLoadReport {
    pub scale: BenchScale,
    /// Set only on hand-authored baselines written without a local
    /// toolchain (see [`SearchSpeedReport::provisional`]).
    pub provisional: bool,
    /// Distinct `(model, seed)` requests per phase.
    pub distinct_requests: usize,
    /// Total submissions across both phases.
    pub total_requests: usize,
    /// Wall time of the whole campaign.
    pub wall_s: f64,
    /// End-to-end throughput across both phases.
    pub requests_per_s: f64,
    /// Cold-phase latency: every request misses the cache and runs a
    /// verified search.
    pub cold: LatencyStats,
    /// Warm-phase latency: every request is a solution-cache hit.
    pub warm: LatencyStats,
    /// Counters read back from the service after the campaign.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// `cold.p50 / warm.p50` — how much a cache hit saves.
    pub hit_speedup: f64,
    /// The service's own live log-bucket histogram digests
    /// (queue-wait / cold search / cache hit / verify), read back after
    /// the campaign. Informational: printed, never serialized or gated,
    /// so committed baselines are untouched.
    pub live_latency: Vec<crate::api::wire::LatencySummary>,
}

impl ServiceLoadReport {
    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::s("toast.bench.service_load/v1")),
            ("scale", Json::s(self.scale.name())),
            ("provisional", Json::Bool(self.provisional)),
            ("distinct_requests", Json::n(self.distinct_requests as f64)),
            ("total_requests", Json::n(self.total_requests as f64)),
            ("wall_s", Json::n(self.wall_s)),
            ("requests_per_s", Json::n(self.requests_per_s)),
            ("cold", self.cold.json()),
            ("warm", self.warm.json()),
            ("cache_hits", Json::n(self.cache_hits as f64)),
            ("cache_misses", Json::n(self.cache_misses as f64)),
            ("hit_speedup", Json::n(self.hit_speedup)),
        ])
    }
}

/// Run the service-load campaign: start an in-process service
/// (single-threaded deterministic searches, verification on, solution
/// cache at its default capacity), submit a distinct-request workload
/// (cold phase: every request is a cache miss and a full verified
/// search), then submit the identical workload again (warm phase: every
/// request is a cache hit). Latency is measured from just before
/// `submit` to response receipt, so queueing and — for hits — the
/// in-admission cache lookup are both priced.
pub fn run_service_load(scale: BenchScale) -> ServiceLoadReport {
    use super::service::{default_request, Service, ServiceConfig};
    use std::collections::HashMap;
    use std::time::Instant;

    let (zoo, seeds, workers): (&[ModelKind], u64, usize) = match scale {
        BenchScale::Tiny => (&[ModelKind::Mlp], 3, 2),
        _ => (&[ModelKind::Mlp, ModelKind::Attention, ModelKind::Itx], 4, 4),
    };
    let svc = Service::start_with(ServiceConfig {
        workers,
        search_threads: 1,
        ..Default::default()
    });

    let mut workload = Vec::new();
    for &mk in zoo {
        for seed in 0..seeds {
            let mut req = default_request(mk, Method::Toast);
            req.budget = scale.budget();
            req.seed = seed;
            workload.push(req);
        }
    }
    let distinct = workload.len();

    let t0 = Instant::now();
    let mut phases: Vec<LatencyStats> = Vec::new();
    for _ in 0..2 {
        let mut submitted: HashMap<u64, Instant> = HashMap::new();
        for req in &workload {
            let t = Instant::now();
            let id = svc.submit(req.clone()).expect("service accepts the load");
            submitted.insert(id, t);
        }
        let mut latencies = Vec::with_capacity(distinct);
        for _ in 0..distinct {
            let resp = svc.responses.recv().expect("service answers the load");
            let t = submitted.remove(&resp.id).expect("response matches a submission");
            let sol = resp.result.expect("load request succeeds");
            assert!(
                sol.validation.as_ref().is_some_and(|v| v.pass),
                "load request came back unverified"
            );
            latencies.push(t.elapsed().as_secs_f64() * 1e3);
        }
        phases.push(LatencyStats::from_samples(latencies));
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let warm = phases.pop().expect("warm phase ran");
    let cold = phases.pop().expect("cold phase ran");
    let cache_hits = svc.metrics.cache_hits.load(std::sync::atomic::Ordering::Relaxed);
    let cache_misses = svc.metrics.cache_misses.load(std::sync::atomic::Ordering::Relaxed);
    let live_latency = svc.metrics.latency_summaries();
    svc.shutdown();

    let total = 2 * distinct;
    ServiceLoadReport {
        scale,
        provisional: false,
        distinct_requests: distinct,
        total_requests: total,
        wall_s,
        requests_per_s: if wall_s > 0.0 { total as f64 / wall_s } else { 0.0 },
        cold,
        warm,
        cache_hits,
        cache_misses,
        hit_speedup: cold.p50_ms / warm.p50_ms.max(1e-6),
        live_latency,
    }
}

/// Gate a fresh service-load report: (a) in-run acceptance gates — the
/// warm phase must be all cache hits and the cold phase all misses
/// (counter-verified), warm p50 below cold p50 always, and a ≥50×
/// hit-speedup floor when `enforce_hit_gate` (tiny-scale smoke runs
/// relax the floor: toy searches finish so fast there is less to save) —
/// and (b) the ±25% band against the committed baseline, downgraded to
/// a warning for `"provisional": true` baselines exactly as
/// [`check_search_speed`] does.
pub fn check_service_load(
    current: &ServiceLoadReport,
    baseline: Option<&Json>,
    enforce_hit_gate: bool,
) -> BenchCheck {
    let mut check = BenchCheck::default();

    if current.cache_misses != current.distinct_requests as u64 {
        check.failures.push(format!(
            "cold phase: expected {} cache misses, service counted {}",
            current.distinct_requests, current.cache_misses
        ));
    }
    if current.cache_hits != current.distinct_requests as u64 {
        check.failures.push(format!(
            "warm phase: expected {} cache hits, service counted {}",
            current.distinct_requests, current.cache_hits
        ));
    }
    if current.warm.p50_ms >= current.cold.p50_ms {
        check.failures.push(format!(
            "cache-hit p50 {:.3}ms is not below search p50 {:.3}ms",
            current.warm.p50_ms, current.cold.p50_ms
        ));
    }
    if enforce_hit_gate && current.hit_speedup < 50.0 {
        check.failures.push(format!(
            "cache-hit speedup {:.0}x below the 50x acceptance gate \
             ({:.3}ms -> {:.3}ms p50)",
            current.hit_speedup, current.cold.p50_ms, current.warm.p50_ms
        ));
    }

    let Some(baseline) = baseline else {
        return check;
    };
    match baseline.get("format").and_then(Json::as_str) {
        Some("toast.bench.service_load/v1") => {}
        other => {
            check
                .failures
                .push(format!("baseline format {other:?} is not toast.bench.service_load/v1"));
            return check;
        }
    }
    if baseline.get("provisional").and_then(Json::as_bool) == Some(true) {
        check.warnings.push(
            "baseline is provisional (hand-authored estimates): ±25% band skipped — \
             re-bless it with `toast bench --experiment service-load --out BENCH_service_load.json`"
                .to_string(),
        );
        return check;
    }

    band_check(
        &mut check,
        "requests_per_s",
        current.requests_per_s,
        baseline.get("requests_per_s").and_then(Json::as_f64),
        true,
    );
    let base_cold = LatencyStats::from_json(baseline.get("cold"));
    let base_warm = LatencyStats::from_json(baseline.get("warm"));
    band_check(&mut check, "cold.p50_ms", current.cold.p50_ms, Some(base_cold.p50_ms), false);
    band_check(&mut check, "warm.p50_ms", current.warm.p50_ms, Some(base_warm.p50_ms), false);
    check
}

/// Render the service-load report as a table.
pub fn format_service_load(r: &ServiceLoadReport) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== service load ({} scale): {} distinct requests x 2 phases ==",
        r.scale.name(),
        r.distinct_requests
    );
    let _ = writeln!(
        out,
        "throughput: {:.1} req/s over {:.2}s wall ({} submissions)",
        r.requests_per_s, r.wall_s, r.total_requests
    );
    for (title, s) in [("cold (search)", &r.cold), ("warm (cache hit)", &r.warm)] {
        let _ = writeln!(
            out,
            "{:<17} p50 {:>10.3}ms  p99 {:>10.3}ms  mean {:>10.3}ms",
            title, s.p50_ms, s.p99_ms, s.mean_ms
        );
    }
    let _ = writeln!(
        out,
        "cache: {} hits / {} misses, hit speedup {:.0}x at p50",
        r.cache_hits, r.cache_misses, r.hit_speedup
    );
    // The service's own log-bucket histograms, measured server-side
    // (client-side stats above include channel hand-off). Informational.
    for l in &r.live_latency {
        let _ = writeln!(
            out,
            "service histogram {:<12} n={:<6} p50 {:>9}us  p99 {:>9}us",
            l.phase, l.count, l.p50_us, l.p99_us
        );
    }
    out
}

/// One row of the differential-validation suite: a `(model, mesh, spec)`
/// triple executed on both executors.
#[derive(Clone, Debug)]
pub struct DiffRow {
    pub model: ModelKind,
    pub mesh: String,
    /// How the spec was produced: `unsharded`, `action-walk`, `random`.
    pub spec_kind: &'static str,
    /// Sharded (value, dim) pairs in the spec.
    pub sharded_dims: usize,
    /// Collectives in the executed device-local module.
    pub collectives: usize,
    /// Worst relative divergence across results.
    pub max_rel_err: f64,
    /// Within tolerance?
    pub pass: bool,
    /// Partition/execution error, when the triple never produced a
    /// comparison (shown in the table so CI failures carry the cause).
    pub error: Option<String>,
}

/// The mesh shapes every scaled zoo model is validated under: two 1-D
/// meshes, a 2-D mesh, and a 2-D mesh with a singleton axis (degenerate
/// subgroups).
pub fn differential_meshes() -> Vec<Mesh> {
    vec![
        Mesh::grid(&[("d", 2)]),
        Mesh::grid(&[("d", 4)]),
        Mesh::grid(&[("a", 2), ("b", 2)]),
        Mesh::grid(&[("a", 1), ("b", 2)]),
    ]
}

/// Deterministic greedy action walk — the single shared trajectory
/// generator behind [`measure_eval_throughput`] and the differential
/// suite's `action-walk` specs: repeatedly apply the first still-legal
/// action, stopping at `depth` actions or at the first prefix the
/// partitioner rejects. Returns the applied action ids and every prefix
/// spec (unsharded root included); each returned spec partitions.
pub fn greedy_action_walk(
    func: &Func,
    mesh: &Mesh,
    actions: &[Action],
    depth: usize,
) -> (Vec<usize>, Vec<ShardingSpec>) {
    let mut specs: Vec<ShardingSpec> = vec![ShardingSpec::unsharded(func)];
    let mut walk: Vec<usize> = Vec::new();
    for _ in 0..depth {
        let spec = specs.last().unwrap();
        let next = (0..actions.len()).find(|&ai| {
            !walk.contains(&ai)
                && spec.check_assignment(func, mesh, &actions[ai].assignment, actions[ai].axis)
        });
        let Some(ai) = next else { break };
        let mut s = spec.clone();
        s.apply_assignment(func, mesh, &actions[ai].assignment, actions[ai].axis)
            .expect("probed action applies");
        if partition(func, &s, mesh).is_err() {
            break;
        }
        walk.push(ai);
        specs.push(s);
    }
    (walk, specs)
}

/// A partitioner-realistic spec for the differential suite: the end
/// state of [`greedy_action_walk`] over the model's NDA action space.
/// The NDA is mesh-independent, so sweeps analyze once per model.
fn action_walk_spec(
    func: &Func,
    nda: &crate::nda::Nda,
    mesh: &Mesh,
    depth: usize,
) -> ShardingSpec {
    let actions = crate::search::build_actions(
        func,
        nda,
        mesh,
        &crate::search::ActionSpaceConfig { min_color_dims: 1, ..Default::default() },
    );
    let (_, specs) = greedy_action_walk(func, mesh, &actions, depth);
    specs.last().unwrap().clone()
}

/// Run the differential-validation suite: every model × every mesh from
/// [`differential_meshes`] × three spec sources (unsharded sanity, a
/// greedy NDA action walk, a seeded random legal spec). Each triple
/// partitions, executes on both executors, and must agree within `tol`
/// relative error. Partition-rejected random specs retry with fresh
/// seeds (a rejected spec has nothing to compare).
pub fn run_differential_suite(models: &[ModelKind], seed: u64, tol: f32) -> Vec<DiffRow> {
    let mut rows = Vec::new();
    for &mk in models {
        let func = mk.build_scaled();
        // Inputs, the oracle run and the NDA depend only on (func, seed):
        // compute once per model, amortized over every (mesh, spec) pair.
        let inputs = crate::runtime::diff::random_inputs(&func, seed);
        let expected = match crate::ir::interp::eval_func(&func, &inputs) {
            Ok(e) => e,
            Err(e) => {
                rows.push(DiffRow {
                    model: mk,
                    mesh: "-".to_string(),
                    spec_kind: "oracle",
                    sharded_dims: 0,
                    collectives: 0,
                    max_rel_err: f64::INFINITY,
                    pass: false,
                    error: Some(format!("oracle execution failed: {e:#}")),
                });
                continue;
            }
        };
        let nda = crate::nda::Nda::analyze(&func);
        for mesh in differential_meshes() {
            let mut specs: Vec<(&'static str, ShardingSpec)> =
                vec![("unsharded", ShardingSpec::unsharded(&func))];
            specs.push(("action-walk", action_walk_spec(&func, &nda, &mesh, 4)));
            let mut rng = Rng::new(seed ^ ((mk as u64) << 8) ^ mesh.num_devices() as u64);
            // A rejected random spec has nothing to compare — retry a few
            // seeds, keeping the first the partitioner accepts.
            for _attempt in 0..5 {
                let cand = crate::runtime::diff::random_legal_spec(&func, &mesh, &mut rng);
                if partition(&func, &cand, &mesh).is_ok() {
                    specs.push(("random", cand));
                    break;
                }
            }
            for (kind, spec) in specs {
                let row = match crate::runtime::diff::differential_test_against(
                    &func, &spec, &mesh, &inputs, &expected,
                ) {
                    Ok(r) => DiffRow {
                        model: mk,
                        mesh: mesh.describe(),
                        spec_kind: kind,
                        sharded_dims: spec.sharded_dim_count(),
                        collectives: r.stats.total_collectives(),
                        max_rel_err: r.max_rel_err as f64,
                        pass: r.max_rel_err <= tol,
                        error: None,
                    },
                    Err(e) => DiffRow {
                        model: mk,
                        mesh: mesh.describe(),
                        spec_kind: kind,
                        sharded_dims: spec.sharded_dim_count(),
                        collectives: 0,
                        max_rel_err: f64::INFINITY,
                        pass: false,
                        error: Some(format!("{e:#}")),
                    },
                };
                rows.push(row);
            }
        }
    }
    rows
}

/// One row of the pipeline-stage sweep: a `(model, stages, mesh, spec)`
/// combination executed on the staged SPMD runtime and priced through
/// both schedule paths.
#[derive(Clone, Debug)]
pub struct PipeRow {
    pub model: ModelKind,
    pub stages: usize,
    pub mesh: String,
    pub spec_kind: &'static str,
    /// Worst relative divergence of staged execution vs. the oracle.
    pub max_rel_err: f64,
    /// Relative gap between symbolic and oracle schedule pricing.
    pub price_gap: f64,
    pub pass: bool,
    pub error: Option<String>,
}

/// Run the pipeline-stage differential sweep: every model × stage count
/// is cut at compute-balanced NDA-legal boundaries and, for two meshes ×
/// {unsharded, action-walk} specs, (a) executed end to end on the staged
/// SPMD simulator against the interpreter oracle (≤ `tol` relative) and
/// (b) priced through both the symbolic and the simulate-then-price
/// schedule paths (≤ 1e-6 relative gap). Stage counts a model's legal
/// boundaries cannot support produce an informational `uncuttable` row
/// that passes.
pub fn run_pipeline_suite(
    models: &[ModelKind],
    stage_counts: &[usize],
    seed: u64,
    tol: f32,
) -> Vec<PipeRow> {
    use crate::pipeline::{self, schedule};
    let mut rows = Vec::new();
    let cost_model = CostModel::new(Topology::from_kind(HardwareKind::A100));
    for &mk in models {
        let func = mk.build_scaled();
        let nda = crate::nda::Nda::analyze(&func);
        let legal = pipeline::legal_boundaries(&func, &nda);
        for &k in stage_counts {
            let Some(bounds) =
                pipeline::balanced_boundaries(&func, &legal, k, pipeline::compute_weight)
            else {
                rows.push(PipeRow {
                    model: mk,
                    stages: k,
                    mesh: "-".to_string(),
                    spec_kind: "uncuttable",
                    max_rel_err: 0.0,
                    price_gap: 0.0,
                    pass: true,
                    error: Some(format!("{} legal boundaries support no {k}-stage cut", legal.len())),
                });
                continue;
            };
            let sm = match pipeline::cut_stages(&func, &bounds) {
                Ok(sm) => sm,
                Err(e) => {
                    rows.push(PipeRow {
                        model: mk,
                        stages: k,
                        mesh: "-".to_string(),
                        spec_kind: "cut",
                        max_rel_err: f64::INFINITY,
                        price_gap: f64::INFINITY,
                        pass: false,
                        error: Some(format!("{e:#}")),
                    });
                    continue;
                }
            };
            for mesh in [Mesh::grid(&[("d", 2)]), Mesh::grid(&[("a", 2), ("b", 2)])] {
                let specs: Vec<(&'static str, ShardingSpec)> = vec![
                    ("unsharded", ShardingSpec::unsharded(&func)),
                    ("action-walk", action_walk_spec(&func, &nda, &mesh, 3)),
                ];
                for (kind, spec) in specs {
                    let diff = crate::runtime::diff::differential_test_staged(
                        &func, &spec, &bounds, &mesh, seed,
                    );
                    let price = schedule::price_staged_symbolic(
                        &sm,
                        &spec,
                        &mesh,
                        &cost_model,
                        8,
                    )
                    .and_then(|a| {
                        schedule::price_staged_oracle(&sm, &spec, &mesh, &cost_model, 8)
                            .map(|b| (a, b))
                    });
                    let (max_rel_err, diff_err) = match &diff {
                        Ok(r) => (r.max_rel_err as f64, None),
                        Err(e) => (f64::INFINITY, Some(format!("{e:#}"))),
                    };
                    let (price_gap, price_err) = match &price {
                        Ok((a, b)) => (
                            (a.cost.runtime_s - b.cost.runtime_s).abs()
                                / b.cost.runtime_s.abs().max(1e-30),
                            None,
                        ),
                        Err(e) => (f64::INFINITY, Some(format!("{e:#}"))),
                    };
                    let pass = max_rel_err <= tol as f64 && price_gap <= 1e-6;
                    rows.push(PipeRow {
                        model: mk,
                        stages: k,
                        mesh: mesh.describe(),
                        spec_kind: kind,
                        max_rel_err,
                        price_gap,
                        pass,
                        error: diff_err.or(price_err),
                    });
                }
            }
        }
    }
    rows
}

/// Render the pipeline sweep as a table. `tol` must be the tolerance the
/// rows' pass/FAIL column was computed with.
pub fn format_pipeline(rows: &[PipeRow], tol: f32) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== pipeline stages (staged SPMD vs. oracle + schedule-pricing agreement) =="
    );
    let _ = writeln!(
        out,
        "{:<10} {:>6} {:<22} {:<12} {:>12} {:>12} {:>6}",
        "model", "stages", "mesh", "spec", "max_rel_err", "price_gap", "ok"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<10} {:>6} {:<22} {:<12} {:>12.3e} {:>12.3e} {:>6}",
            r.model.name(),
            r.stages,
            r.mesh,
            r.spec_kind,
            r.max_rel_err,
            r.price_gap,
            if r.pass { "pass" } else { "FAIL" }
        );
        if let Some(err) = &r.error {
            let _ = writeln!(out, "    ^ {err}");
        }
    }
    let failed = rows.iter().filter(|r| !r.pass).count();
    let _ = writeln!(out, "{} rows, {} failed (exec tol {:.1e}, price tol 1e-6)", rows.len(), failed, tol);
    out
}

/// One row of the MoE expert-parallel comparison (`bench --experiment
/// moe`): on a mesh whose first axis is a dedicated expert axis, the
/// best expert(×data)-sharded plan against the best pure-data plan.
#[derive(Clone, Debug)]
pub struct MoeRow {
    pub mesh: String,
    /// Priced relative cost of the expert(×data) plan.
    pub expert_rel: f64,
    /// Priced relative cost of the pure-data plan.
    pub data_rel: f64,
    /// `all_to_all` count in the partitioned expert plan (the routed
    /// dispatch/combine reshards).
    pub all_to_all: usize,
    /// Relative gap between the expert plan's symbolic price and the
    /// materialize-and-evaluate oracle (gated at 1e-6).
    pub price_gap: f64,
    /// Differential error of the expert plan on the SPMD simulator.
    pub max_rel_err: f64,
    pub pass: bool,
    pub error: Option<String>,
}

/// Run the MoE expert-parallel smoke (tiny scale, forward graph): for a
/// 1-D `expert` mesh and a 2-D `expert × data` mesh, build the NDA
/// action space, assemble (a) the cheapest plan that shards the expert
/// dim (layer-0 `w1` dim 0) on the expert axis — completed with
/// token-sharding on any remaining axis — and (b) the cheapest pure-data
/// plan (token dim on every axis that accepts it). The expert plan must
/// price below the data plan, carry `all_to_all` reshards, agree with
/// the pricing oracle to 1e-6, and pass the differential gate.
pub fn run_moe_suite(seed: u64, tol: f32) -> Vec<MoeRow> {
    use crate::models::moe;
    let cfg = moe::MoeConfig { training: false, ..moe::MoeConfig::tiny() };
    let (func, _, _) = moe::forward(&cfg);
    let nda = crate::nda::Nda::analyze(&func);
    let meshes = [Mesh::grid(&[("expert", 2)]), Mesh::grid(&[("expert", 2), ("data", 2)])];
    meshes.iter().map(|mesh| moe_row(&func, &nda, mesh, seed, tol)).collect()
}

fn moe_row(
    func: &Func,
    nda: &crate::nda::Nda,
    mesh: &Mesh,
    seed: u64,
    tol: f32,
) -> MoeRow {
    use crate::ir::ValueId;
    let fail = |err: String| MoeRow {
        mesh: mesh.describe(),
        expert_rel: f64::INFINITY,
        data_rel: f64::INFINITY,
        all_to_all: 0,
        price_gap: f64::INFINITY,
        max_rel_err: f64::INFINITY,
        pass: false,
        error: Some(err),
    };
    // Stable param layout: x, then (wg, w1, w2, route) per layer.
    let Some(w1) = func.params.iter().position(|p| p.name == "l0_w1") else {
        return fail("no l0_w1 param".to_string());
    };
    let (w1, x) = (ValueId(w1 as u32), ValueId(0));
    let model = CostModel::new(Topology::from_kind(HardwareKind::A100));
    let actions = crate::search::build_actions(
        func,
        nda,
        mesh,
        &crate::search::ActionSpaceConfig { min_color_dims: 1, ..Default::default() },
    );
    let shards = |a: &Action, v: ValueId, d: usize| a.assignment.contains(&(v, d));
    // Greedily extend `spec` with the first applicable token-sharding
    // action on each axis in `axes` (pure data parallelism).
    let add_data = |spec: &mut ShardingSpec, axes: &[usize]| {
        for &ax in axes {
            for a in actions.iter().filter(|a| a.axis == ax && shards(a, x, 1)) {
                if spec.check_assignment(func, mesh, &a.assignment, a.axis)
                    && spec.apply_assignment(func, mesh, &a.assignment, a.axis).is_ok()
                {
                    break;
                }
            }
        }
    };
    let seval = SymbolicEvaluator::new(func, mesh, &model);
    let base = match seval.evaluate(&ShardingSpec::unsharded(func)) {
        Ok((c, _)) => c,
        Err(e) => return fail(format!("base evaluation failed: {e:#}")),
    };
    let data_axes: Vec<usize> = (1..mesh.axes.len()).collect();
    let all_axes: Vec<usize> = (0..mesh.axes.len()).collect();

    // Expert plan: each expert-dim resolution on axis 0, completed with
    // token sharding on the remaining axes; keep the cheapest.
    let mut expert: Option<(f64, ShardingSpec)> = None;
    for a in actions.iter().filter(|a| a.axis == 0 && shards(a, w1, 0)) {
        let mut spec = ShardingSpec::unsharded(func);
        if !spec.check_assignment(func, mesh, &a.assignment, a.axis)
            || spec.apply_assignment(func, mesh, &a.assignment, a.axis).is_err()
        {
            continue;
        }
        add_data(&mut spec, &data_axes);
        let Ok((c, _)) = seval.evaluate(&spec) else { continue };
        let rel = model.relative(&c, &base);
        if expert.as_ref().map_or(true, |(best, _)| rel < *best) {
            expert = Some((rel, spec));
        }
    }
    let Some((expert_rel, expert_spec)) = expert else {
        return fail("no applicable expert-sharding action on the expert axis".to_string());
    };

    // Pure-data plan: token sharding on every axis that accepts it.
    let mut data_spec = ShardingSpec::unsharded(func);
    add_data(&mut data_spec, &all_axes);
    let data_rel = match seval.evaluate(&data_spec) {
        Ok((c, _)) => model.relative(&c, &base),
        Err(e) => return fail(format!("data plan evaluation failed: {e:#}")),
    };

    // Pin the symbolic price to the materialize-and-evaluate oracle.
    let (local, stats) = match partition(func, &expert_spec, mesh) {
        Ok(r) => r,
        Err(e) => return fail(format!("expert plan partition failed: {e:#}")),
    };
    let oracle_rel = model.relative(&model.evaluate(&local, mesh), &base);
    let price_gap = (expert_rel - oracle_rel).abs() / oracle_rel.max(1e-12);

    let report = match crate::runtime::diff::differential_test(func, &expert_spec, mesh, seed) {
        Ok(r) => r,
        Err(e) => return fail(format!("differential execution failed: {e:#}")),
    };
    let max_rel_err = report.max_rel_err as f64;
    MoeRow {
        mesh: mesh.describe(),
        expert_rel,
        data_rel,
        all_to_all: stats.all_to_all,
        price_gap,
        max_rel_err,
        pass: expert_rel < data_rel
            && stats.all_to_all >= 2
            && price_gap <= 1e-6
            && max_rel_err as f32 <= tol,
        error: None,
    }
}

/// Render the MoE suite as a table.
pub fn format_moe(rows: &[MoeRow], tol: f32) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "== MoE expert parallelism (expert(xdata) plan vs pure-data plan) ==");
    let _ = writeln!(
        out,
        "{:<22} {:>12} {:>12} {:>6} {:>12} {:>12} {:>6}",
        "mesh", "expert_rel", "data_rel", "a2a", "price_gap", "max_rel_err", "ok"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<22} {:>12.4} {:>12.4} {:>6} {:>12.3e} {:>12.3e} {:>6}",
            r.mesh,
            r.expert_rel,
            r.data_rel,
            r.all_to_all,
            r.price_gap,
            r.max_rel_err,
            if r.pass { "pass" } else { "FAIL" }
        );
        if let Some(err) = &r.error {
            let _ = writeln!(out, "    ^ {err}");
        }
    }
    let failed = rows.iter().filter(|r| !r.pass).count();
    let _ = writeln!(
        out,
        "{} meshes, {} failed (exec tol {:.1e}, price tol 1e-6)",
        rows.len(),
        failed,
        tol
    );
    out
}

/// One row of the topology sweep (`bench --experiment topology`). Three
/// arm kinds share the table: one row per committed profile (winning
/// plan plus the worst pricing-path gaps over every plan), a
/// `cross-profile` row (the two profiles must crown different winners,
/// with the island winner clearly cheaper under island pricing), and a
/// `staged` row (stage-to-stage transfers priced against the stage tier
/// on both profiles).
#[derive(Clone, Debug)]
pub struct TopologyRow {
    /// Profile name, `cross-profile`, or `staged`.
    pub arm: String,
    /// Winning plan (profile rows), winner pairing (cross row), or the
    /// staged cut (staged row).
    pub detail: String,
    /// Winner's relative cost (profile rows), the flat winner's relative
    /// cost under island pricing (cross row), or the island/flat staged
    /// runtime ratio (staged row).
    pub rel: f64,
    /// Worst symbolic-vs-oracle relative gap in the arm (gated at 1e-6).
    pub price_gap: f64,
    /// Worst incremental-vs-oracle relative gap (profile rows only).
    pub incr_gap: f64,
    pub pass: bool,
    pub error: Option<String>,
}

/// Run the topology sweep: a wide MLP on a 2-D `intra × island` mesh,
/// priced against the two committed profiles `a100-flat-8` (all-NVLink)
/// and `a100-2x4-islands` (NVLink inside a 4-GPU island, a 25 GB/s
/// spine between the two islands). The batch (771 = 3·257) is divisible
/// by neither mesh axis, so every legal plan is Megatron hidden
/// sharding on some axis subset and the winner is decided purely by
/// where the resolving `all_reduce` rides: the flat profile spreads the
/// hidden dim over all 8 devices, the island profile keeps the
/// collective inside the NVLink island. Each profile arm pins symbolic
/// and incremental pricing to the materialize-and-evaluate oracle on
/// every plan; the cross arm requires different winners with the island
/// choice clearly cheaper under island pricing; the staged arm requires
/// the stage hop to price at the stage tier on both profiles.
pub fn run_topology_suite() -> Vec<TopologyRow> {
    use crate::ir::{FuncBuilder, TensorType, ValueId};

    let mut b = FuncBuilder::new("topo_mlp");
    let x = b.param("x", TensorType::f32(vec![771, 4096]));
    let w1 = b.param("w1", TensorType::f32(vec![4096, 8192]));
    let w2 = b.param("w2", TensorType::f32(vec![8192, 1024]));
    let y = b.matmul(x, w1);
    let z = b.relu(y);
    let out = b.matmul(z, w2);
    let func = b.build(vec![out]);
    let mesh = Mesh::grid(&[("intra", 4), ("island", 2)]);
    // Megatron hidden sharding: w1 cols, the activations, w2 rows — the
    // contraction of the second matmul, resolved by one all_reduce per
    // sharding axis.
    let megatron: Vec<(ValueId, usize)> = vec![(w1, 1), (y, 1), (z, 1), (w2, 0)];
    let plans: [(&str, &[usize]); 3] = [
        ("hidden:intra", &[0]),
        ("hidden:island", &[1]),
        ("hidden:intra+island", &[0, 1]),
    ];

    let fail = |arm: &str, err: String| TopologyRow {
        arm: arm.to_string(),
        detail: String::new(),
        rel: f64::INFINITY,
        price_gap: f64::INFINITY,
        incr_gap: f64::INFINITY,
        pass: false,
        error: Some(err),
    };

    let mut rows = Vec::new();
    let mut winners = Vec::new();
    for name in ["a100-flat-8", "a100-2x4-islands"] {
        let topo = Topology::named(name).expect("committed preset");
        match topology_profile_row(&func, &mesh, &megatron, &plans, topo) {
            Ok((row, wi)) => {
                winners.push((wi, row.rel));
                rows.push(row);
            }
            Err(e) => rows.push(fail(name, e)),
        }
    }

    // Cross-profile arm: hierarchical pricing must change the decision,
    // not just the number — different winners, and the island profile's
    // choice must clearly beat the flat profile's choice *under island
    // pricing*.
    if let [(flat_wi, _), (island_wi, island_rel)] = winners[..] {
        let model =
            CostModel::new(Topology::named("a100-2x4-islands").expect("committed preset"));
        let sym = SymbolicEvaluator::new(&func, &mesh, &model);
        let row = match partition(&func, &ShardingSpec::unsharded(&func), &mesh) {
            Ok((local, _)) => {
                let base = model.evaluate(&local, &mesh);
                let mut spec = ShardingSpec::unsharded(&func);
                let ok = plans[flat_wi]
                    .1
                    .iter()
                    .all(|&ax| spec.apply_assignment(&func, &mesh, &megatron, ax).is_ok());
                if ok {
                    let flat_on_island = sym.relative(&spec, &base);
                    TopologyRow {
                        arm: "cross-profile".to_string(),
                        detail: format!(
                            "flat picks {}, islands pick {}",
                            plans[flat_wi].0, plans[island_wi].0
                        ),
                        rel: flat_on_island,
                        price_gap: 0.0,
                        incr_gap: 0.0,
                        pass: flat_wi != island_wi && island_rel < 0.9 * flat_on_island,
                        error: None,
                    }
                } else {
                    fail("cross-profile", "flat winner does not re-apply".to_string())
                }
            }
            Err(e) => fail("cross-profile", format!("identity partition failed: {e:#}")),
        };
        rows.push(row);
    } else {
        rows.push(fail(
            "cross-profile",
            "profile arms failed; nothing to compare".to_string(),
        ));
    }

    rows.push(staged_topology_row(&func, &mesh));
    rows
}

/// One profile arm of the topology sweep: price every plan through all
/// three paths, return the arm row plus the winning plan's index.
fn topology_profile_row(
    func: &Func,
    mesh: &Mesh,
    megatron: &[(crate::ir::ValueId, usize)],
    plans: &[(&str, &[usize])],
    topo: Topology,
) -> Result<(TopologyRow, usize), String> {
    let arm = topo.name.clone();
    let model = CostModel::new(topo);
    let sym = SymbolicEvaluator::new(func, mesh, &model);
    let base = partition(func, &ShardingSpec::unsharded(func), mesh)
        .map(|(local, _)| model.evaluate(&local, mesh))
        .map_err(|e| format!("identity partition failed: {e:#}"))?;
    let mut eng =
        IncrementalEvaluator::with_shared_rules(func, mesh, &model, base, sym.shared_rules())
            .map_err(|e| format!("incremental engine failed: {e:#}"))?;

    let mut best: Option<(f64, usize)> = None;
    let (mut price_gap, mut incr_gap) = (0.0f64, 0.0f64);
    for (i, (name, axes)) in plans.iter().enumerate() {
        let mut spec = ShardingSpec::unsharded(func);
        eng.reset();
        for &ax in *axes {
            spec.apply_assignment(func, mesh, megatron, ax)
                .map_err(|e| format!("plan {name}: {e}"))?;
            eng.apply(megatron, ax).map_err(|e| format!("plan {name}: {e}"))?;
        }
        let (local, _) = partition(func, &spec, mesh)
            .map_err(|e| format!("plan {name}: partition failed: {e:#}"))?;
        let oracle_rel = model.relative(&model.evaluate(&local, mesh), &base);
        let sym_rel = sym.relative(&spec, &base);
        let incr_rel = eng.relative();
        price_gap = price_gap.max((sym_rel - oracle_rel).abs() / oracle_rel.max(1e-12));
        incr_gap = incr_gap.max((incr_rel - oracle_rel).abs() / oracle_rel.max(1e-12));
        if best.map_or(true, |(r, _)| sym_rel < r) {
            best = Some((sym_rel, i));
        }
    }
    let (winner_rel, wi) = best.ok_or_else(|| "no plans enumerated".to_string())?;
    Ok((
        TopologyRow {
            arm,
            detail: plans[wi].0.to_string(),
            rel: winner_rel,
            price_gap,
            incr_gap,
            pass: price_gap <= 1e-6 && incr_gap <= 1e-6,
            error: None,
        },
        wi,
    ))
}

/// The staged arm: cut the sweep MLP at its first legal boundary, price
/// the two-stage schedule symbolically and through the materialized
/// oracle on both profiles, and require (a) both paths agree to 1e-6 on
/// each profile and (b) the island profile prices the schedule strictly
/// higher — its stage-to-stage hop rides the outermost (spine) tier.
fn staged_topology_row(func: &Func, mesh: &Mesh) -> TopologyRow {
    use crate::pipeline::{self, schedule};
    let fail = |err: String| TopologyRow {
        arm: "staged".to_string(),
        detail: String::new(),
        rel: f64::INFINITY,
        price_gap: f64::INFINITY,
        incr_gap: 0.0,
        pass: false,
        error: Some(err),
    };
    let nda = crate::nda::Nda::analyze(func);
    let legal = pipeline::legal_boundaries(func, &nda);
    let Some(&cut) = legal.first() else {
        return fail("no legal stage boundary".to_string());
    };
    let sm = match pipeline::cut_stages(func, &[cut]) {
        Ok(sm) => sm,
        Err(e) => return fail(format!("cut failed: {e:#}")),
    };
    let spec = ShardingSpec::unsharded(func);
    let mut runtimes = Vec::new();
    let mut gap: f64 = 0.0;
    for name in ["a100-flat-8", "a100-2x4-islands"] {
        let model = CostModel::new(Topology::named(name).expect("committed preset"));
        let sc_sym = match schedule::price_staged_symbolic(&sm, &spec, mesh, &model, 4) {
            Ok(sc) => sc,
            Err(e) => return fail(format!("{name}: symbolic staged pricing failed: {e:#}")),
        };
        let sc_or = match schedule::price_staged_oracle(&sm, &spec, mesh, &model, 4) {
            Ok(sc) => sc,
            Err(e) => return fail(format!("{name}: oracle staged pricing failed: {e:#}")),
        };
        gap = gap.max(
            (sc_sym.cost.runtime_s - sc_or.cost.runtime_s).abs()
                / sc_or.cost.runtime_s.max(1e-12),
        );
        runtimes.push(sc_or.cost.runtime_s);
    }
    let ratio = runtimes[1] / runtimes[0].max(1e-12);
    TopologyRow {
        arm: "staged".to_string(),
        detail: format!("2 stages, cut at {cut}, m=4"),
        rel: ratio,
        price_gap: gap,
        incr_gap: 0.0,
        pass: gap <= 1e-6 && ratio > 1.0,
        error: None,
    }
}

/// Render the topology sweep as a table.
pub fn format_topology(rows: &[TopologyRow]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== topology sweep (flat NVLink vs 2x4 islands; three pricing paths) =="
    );
    let _ = writeln!(
        out,
        "{:<18} {:<36} {:>10} {:>12} {:>12} {:>6}",
        "arm", "detail", "rel", "price_gap", "incr_gap", "ok"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<18} {:<36} {:>10.4} {:>12.3e} {:>12.3e} {:>6}",
            r.arm,
            r.detail,
            r.rel,
            r.price_gap,
            r.incr_gap,
            if r.pass { "pass" } else { "FAIL" }
        );
        if let Some(err) = &r.error {
            let _ = writeln!(out, "    ^ {err}");
        }
    }
    let failed = rows.iter().filter(|r| !r.pass).count();
    let _ = writeln!(out, "{} arms, {} failed (price tol 1e-6)", rows.len(), failed);
    out
}

/// Render the differential suite as a table. `tol` must be the
/// tolerance the rows' pass/FAIL column was computed with.
pub fn format_differential(rows: &[DiffRow], tol: f32) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "== differential validation (SPMD simulator vs. interpreter oracle) ==");
    let _ = writeln!(
        out,
        "{:<10} {:<22} {:<12} {:>6} {:>6} {:>12} {:>6}",
        "model", "mesh", "spec", "dims", "colls", "max_rel_err", "ok"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<10} {:<22} {:<12} {:>6} {:>6} {:>12.3e} {:>6}",
            r.model.name(),
            r.mesh,
            r.spec_kind,
            r.sharded_dims,
            r.collectives,
            r.max_rel_err,
            if r.pass { "pass" } else { "FAIL" }
        );
        if let Some(err) = &r.error {
            let _ = writeln!(out, "    ^ {err}");
        }
    }
    let failed = rows.iter().filter(|r| !r.pass).count();
    let _ = writeln!(out, "{} triples, {} failed (tol {:.1e})", rows.len(), failed, tol);
    out
}

/// Render a Fig-8-style table (step time).
pub fn format_fig8(rows: &[GridRow]) -> String {
    format_grid(
        rows,
        |r| {
            if r.oom {
                format!("{:>10}", "OOM")
            } else if r.step_ms < 0.1 {
                format!("{:>8.2}us", r.step_ms * 1e3)
            } else {
                format!("{:>8.3}ms", r.step_ms)
            }
        },
        "step time, 16 devices — Figure 8",
    )
}

/// Render a Fig-9-style table (search time).
pub fn format_fig9(rows: &[GridRow]) -> String {
    format_grid(rows, |r| format!("{:>10.2}", r.search_s), "search time (s) — Figure 9")
}

fn format_grid(
    rows: &[GridRow],
    cell: impl Fn(&GridRow) -> String,
    title: &str,
) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==");
    let methods: Vec<Method> = {
        let mut v: Vec<Method> = Vec::new();
        for r in rows {
            if !v.contains(&r.method) {
                v.push(r.method);
            }
        }
        v
    };
    let _ = write!(out, "{:<10} {:<7}", "model", "hw");
    for m in &methods {
        let _ = write!(out, " {:>10}", m.name());
    }
    let _ = writeln!(out);
    let mut seen = Vec::new();
    for r in rows {
        let key = (r.model, r.hardware);
        if seen.contains(&key) {
            continue;
        }
        seen.push(key);
        let _ = write!(out, "{:<10} {:<7}", r.model.name(), r.hardware.name());
        for m in &methods {
            if let Some(row) = rows
                .iter()
                .find(|x| x.model == r.model && x.hardware == r.hardware && x.method == *m)
            {
                let _ = write!(out, " {}", cell(row));
            } else {
                let _ = write!(out, " {:>10}", "-");
            }
        }
        let _ = writeln!(out);
    }
    out
}

/// Render the Fig-10 table.
pub fn format_fig10(points: &[(i64, String, Vec<GridRow>)]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "== T2B sequence scaling (step ms / search s) — Figure 10 ==");
    let _ = writeln!(
        out,
        "{:<8} {:<28} {:>16} {:>16} {:>16} {:>16}",
        "seq", "mesh", "Manual", "Alpa", "AutoMap", "TOAST"
    );
    for (seq, mesh, rows) in points {
        let _ = write!(out, "{seq:<8} {mesh:<28}");
        for m in [Method::Manual, Method::Alpa, Method::AutoMap, Method::Toast] {
            if let Some(r) = rows.iter().find(|r| r.method == m) {
                let cellstr = if r.oom {
                    format!("OOM/{:.1}s", r.search_s)
                } else {
                    format!("{:.2}ms/{:.1}s", r.step_ms, r.search_s)
                };
                let _ = write!(out, " {cellstr:>16}");
            }
        }
        let _ = writeln!(out);
    }
    out
}

/// Rows → JSON array for EXPERIMENTS.md bookkeeping.
pub fn grid_json(rows: &[GridRow]) -> String {
    Json::Arr(rows.iter().map(|r| r.json()).collect()).render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_grid_runs_all_methods() {
        let rows = run_grid(
            BenchScale::Tiny,
            &[ModelKind::Mlp],
            &[HardwareKind::A100],
            &Method::all(),
        );
        assert_eq!(rows.len(), 4);
        let table = format_fig8(&rows);
        assert!(table.contains("TOAST"));
        assert!(table.contains("mlp"));
        let json = grid_json(&rows);
        assert!(json.contains("\"method\":\"TOAST\""));
    }

    #[test]
    fn eval_throughput_measures_all_three_evaluators() {
        let func = build_model(ModelKind::Mlp, BenchScale::Tiny);
        let mesh = Mesh::grid(&[("data", 2), ("model", 2)]);
        let model = CostModel::new(Topology::from_kind(HardwareKind::A100));
        let nda = crate::nda::Nda::analyze(&func);
        let actions = crate::search::build_actions(
            &func,
            &nda,
            &mesh,
            &crate::search::ActionSpaceConfig { min_color_dims: 1, ..Default::default() },
        );
        let tp = measure_eval_throughput(&func, &mesh, &model, &actions, 4, 2);
        assert!(tp.oracle_evals_per_s > 0.0);
        assert!(tp.symbolic_evals_per_s > 0.0);
        assert!(tp.incremental_evals_per_s > 0.0);
        assert!(tp.format().contains("evals/sec"));
    }

    #[test]
    fn differential_suite_mlp_passes() {
        use crate::runtime::diff::DEFAULT_REL_TOL;
        let rows = run_differential_suite(&[ModelKind::Mlp], 11, DEFAULT_REL_TOL);
        // 4 meshes x at least (unsharded + action-walk)
        assert!(rows.len() >= 8, "rows {}", rows.len());
        assert!(
            rows.iter().all(|r| r.pass),
            "differential suite failed:\n{}",
            format_differential(&rows, DEFAULT_REL_TOL)
        );
        assert!(format_differential(&rows, DEFAULT_REL_TOL).contains("differential validation"));
    }

    #[test]
    fn moe_suite_expert_plan_beats_data_plan() {
        use crate::runtime::diff::DEFAULT_REL_TOL;
        let rows = run_moe_suite(13, DEFAULT_REL_TOL);
        assert_eq!(rows.len(), 2);
        assert!(
            rows.iter().all(|r| r.pass),
            "moe suite failed:\n{}",
            format_moe(&rows, DEFAULT_REL_TOL)
        );
        assert!(format_moe(&rows, DEFAULT_REL_TOL).contains("expert parallelism"));
    }

    #[test]
    fn topology_suite_flat_and_island_pick_different_winners() {
        let rows = run_topology_suite();
        assert_eq!(rows.len(), 4, "two profile arms + cross-profile + staged");
        assert!(
            rows.iter().all(|r| r.pass),
            "topology suite failed:\n{}",
            format_topology(&rows)
        );
        assert!(format_topology(&rows).contains("topology sweep"));
    }

    #[test]
    fn pipeline_suite_mlp_passes() {
        use crate::runtime::diff::DEFAULT_REL_TOL;
        let rows = run_pipeline_suite(&[ModelKind::Mlp], &[2], 11, DEFAULT_REL_TOL);
        assert!(!rows.is_empty());
        assert!(
            rows.iter().all(|r| r.pass),
            "pipeline suite failed:\n{}",
            format_pipeline(&rows, DEFAULT_REL_TOL)
        );
        assert!(format_pipeline(&rows, DEFAULT_REL_TOL).contains("pipeline stages"));
    }

    #[test]
    fn seq_scaling_tiny_runs() {
        let points = run_seq_scaling(BenchScale::Tiny);
        assert_eq!(points.len(), 2);
        let table = format_fig10(&points);
        assert!(table.contains("sequence scaling"));
    }

    #[test]
    fn search_speed_tiny_report_roundtrips_and_self_checks() {
        let report = run_search_speed(BenchScale::Tiny);
        assert_eq!(report.eval_throughput.len(), 1);
        assert_eq!(report.zoo_joint.len(), 1);
        assert!(report.joint.cost_parity(), "optimized joint search regressed cost");
        assert!(report.flat.opt_evals <= BenchScale::Tiny.budget() * 2, "budget overshoot");

        let rendered = report.json().render();
        let parsed = Json::parse(&rendered).expect("report json parses");
        assert_eq!(
            parsed.get("format").and_then(Json::as_str),
            Some("toast.bench.search_speed/v1")
        );

        // Self-comparison stays inside the ±25% band; the 1.3x speed gate
        // is relaxed at tiny scale where toy models leave nothing to
        // amortize.
        let check = check_search_speed(&report, Some(&parsed), false);
        assert!(check.failures.is_empty(), "self-check failed: {:?}", check.failures);

        // A provisional baseline downgrades the band to a warning.
        let mut provisional = report.clone();
        provisional.provisional = true;
        let base = Json::parse(&provisional.json().render()).unwrap();
        let check = check_search_speed(&report, Some(&base), false);
        assert!(check.failures.is_empty());
        assert!(check.warnings.iter().any(|w| w.contains("provisional")));

        assert!(format_search_speed(&report).contains("search speed"));
    }

    /// The service-load campaign self-checks at tiny scale: the warm
    /// phase is all cache hits, the report round-trips through JSON, and
    /// a provisional baseline downgrades the band to a warning.
    #[test]
    fn service_load_tiny_report_roundtrips_and_self_checks() {
        let report = run_service_load(BenchScale::Tiny);
        assert_eq!(report.distinct_requests, 3);
        assert_eq!(report.total_requests, 6);
        assert_eq!(report.cache_misses, 3, "cold phase must miss");
        assert_eq!(report.cache_hits, 3, "warm phase must hit");
        assert!(
            report.warm.p50_ms < report.cold.p50_ms,
            "cache hit p50 {} not below search p50 {}",
            report.warm.p50_ms,
            report.cold.p50_ms
        );

        let rendered = report.json().render();
        let parsed = Json::parse(&rendered).expect("report json parses");
        assert_eq!(
            parsed.get("format").and_then(Json::as_str),
            Some("toast.bench.service_load/v1")
        );

        // The 50x hit gate is relaxed at tiny scale (toy searches finish
        // fast); self-comparison stays inside the ±25% band.
        let check = check_service_load(&report, Some(&parsed), false);
        assert!(check.failures.is_empty(), "self-check failed: {:?}", check.failures);

        let mut provisional = report.clone();
        provisional.provisional = true;
        let base = Json::parse(&provisional.json().render()).unwrap();
        let check = check_service_load(&report, Some(&base), false);
        assert!(check.failures.is_empty());
        assert!(check.warnings.iter().any(|w| w.contains("provisional")));

        assert!(format_service_load(&report).contains("service load"));
    }
}
