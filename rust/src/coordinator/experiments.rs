//! Experiment runners regenerating the paper's evaluation (§5):
//!
//! * **Fig 8** — partitioned model step time (ms), per model × platform ×
//!   method, 16 devices.
//! * **Fig 9** — auto-sharding search time (s), same grid.
//! * **Fig 10** — T2B sequence-length scaling on a 3-D Batch×Seq×Model
//!   mesh: step time and search time vs sequence length/devices.
//! * **Ablations** — conflict-resolution actions, action-space pruning
//!   threshold, and parameter-group mirroring (the DESIGN.md §7 switches).
//!
//! Absolute milliseconds come from the shared analytic cost model (this
//! testbed has no accelerators); the *shape* of the comparison — who
//! wins, where OOMs appear, how search time scales — is the
//! reproduction target (DESIGN.md §3).

use crate::baselines::{run_method, Method, MethodResult};
use crate::cost::CostModel;
use crate::ir::Func;
use crate::mesh::{HardwareKind, HardwareProfile, Mesh};
use crate::models::{gns, itx, transformer, unet, ModelKind};
use crate::util::json::Json;

/// How big the experiment models are.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BenchScale {
    /// Interpreter-sized (seconds; used by tests).
    Tiny,
    /// Structure-preserving mid-size (default for `cargo bench`).
    Bench,
    /// The paper's full-size IR (minutes).
    Paper,
}

impl BenchScale {
    pub fn budget(self) -> usize {
        match self {
            BenchScale::Tiny => 60,
            BenchScale::Bench => 150,
            BenchScale::Paper => 300,
        }
    }
}

/// Which experiment to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Experiment {
    Fig8,
    Fig9,
    Fig10,
    Ablations,
}

impl std::str::FromStr for Experiment {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "fig8" => Ok(Experiment::Fig8),
            "fig9" => Ok(Experiment::Fig9),
            "fig10" => Ok(Experiment::Fig10),
            "ablations" => Ok(Experiment::Ablations),
            other => Err(format!("unknown experiment '{other}' (fig8|fig9|fig10|ablations)")),
        }
    }
}

/// Build a model at the requested scale (structure-preserving shrink for
/// `Bench`).
pub fn build_model(kind: ModelKind, scale: BenchScale) -> Func {
    match scale {
        BenchScale::Tiny => kind.build_scaled(),
        BenchScale::Paper => kind.build_paper(),
        BenchScale::Bench => match kind {
            ModelKind::T2B => transformer::training_step(&transformer::TransformerConfig {
                d_model: 512,
                layers: 4,
                hidden: 2048,
                heads: 8,
                key_size: 64,
                vocab: 8192,
                batch: 16,
                seq: 512,
                training: true,
            }),
            ModelKind::T7B => transformer::training_step(&transformer::TransformerConfig {
                d_model: 768,
                layers: 6,
                hidden: 3072,
                heads: 12,
                key_size: 64,
                vocab: 8192,
                batch: 16,
                seq: 512,
                training: true,
            }),
            ModelKind::Gns => gns::training_step(&gns::GnsConfig {
                n_nodes: 512,
                n_edges: 2048,
                latent: 256,
                hidden: 128,
                steps: 8,
                training: true,
            }),
            ModelKind::UNet => unet::training_step(&unet::UNetConfig {
                batch: 8,
                size: 32,
                in_channels: 4,
                base_channels: 64,
                channel_mults: vec![1, 2],
                down_blocks_per_level: 2,
                up_blocks_per_level: 2,
                attn_heads: 8,
                training: true,
            }),
            ModelKind::Itx => itx::inference_step(&itx::ItxConfig {
                d_model: 256,
                layers: 6,
                hidden: 1024,
                heads: 8,
                vocab: 8192,
                batch: 8,
                cache_len: 512,
            }),
            other => other.build_scaled(),
        },
    }
}

/// One grid point result.
#[derive(Clone, Debug)]
pub struct GridRow {
    pub model: ModelKind,
    pub hardware: HardwareKind,
    pub method: Method,
    pub step_ms: f64,
    pub search_s: f64,
    pub oom: bool,
    pub relative: f64,
    pub peak_gib: f64,
}

impl GridRow {
    fn from(model: ModelKind, hardware: HardwareKind, r: &MethodResult) -> GridRow {
        GridRow {
            model,
            hardware,
            method: r.method,
            step_ms: r.step_time_s * 1e3,
            search_s: r.search_time.as_secs_f64(),
            oom: r.oom,
            relative: r.relative,
            peak_gib: r.cost.peak_bytes as f64 / (1u64 << 30) as f64,
        }
    }

    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::s(self.model.name())),
            ("hardware", Json::s(self.hardware.name())),
            ("method", Json::s(self.method.name())),
            ("step_ms", Json::n(self.step_ms)),
            ("search_s", Json::n(self.search_s)),
            ("oom", Json::Bool(self.oom)),
            ("relative", Json::n(self.relative)),
            ("peak_gib", Json::n(self.peak_gib)),
        ])
    }
}

/// The Fig 8/9 grid: models × platforms × methods on a 16-device 2-D mesh.
pub fn run_grid(
    scale: BenchScale,
    models: &[ModelKind],
    hardware: &[HardwareKind],
    methods: &[Method],
) -> Vec<GridRow> {
    let mut rows = Vec::new();
    for &mk in models {
        let func = build_model(mk, scale);
        for &hw in hardware {
            let mesh = Mesh::grid(&[("data", 4), ("model", 4)]);
            let model = CostModel::new(HardwareProfile::new(hw));
            for &method in methods {
                let r = run_method(method, mk, &func, &mesh, &model, scale.budget(), 17);
                rows.push(GridRow::from(mk, hw, &r));
            }
        }
    }
    rows
}

/// Fig 10: T2B sequence scaling on a 3-D mesh (Batch × Seq × Model).
/// Returns `(seq_len, mesh description, rows)` triples.
pub fn run_seq_scaling(scale: BenchScale) -> Vec<(i64, String, Vec<GridRow>)> {
    // (seq, mesh) pairs; paper goes to 32k over 2x32x2 = 128 devices.
    let points: Vec<(i64, Vec<(&str, usize)>)> = match scale {
        BenchScale::Tiny => vec![
            (256, vec![("batch", 2), ("seq", 2), ("model", 2)]),
            (512, vec![("batch", 2), ("seq", 4), ("model", 2)]),
        ],
        BenchScale::Bench => vec![
            (1024, vec![("batch", 2), ("seq", 4), ("model", 2)]),
            (4096, vec![("batch", 2), ("seq", 8), ("model", 2)]),
            (8192, vec![("batch", 2), ("seq", 16), ("model", 2)]),
        ],
        BenchScale::Paper => vec![
            (2048, vec![("batch", 2), ("seq", 8), ("model", 2)]),
            (8192, vec![("batch", 2), ("seq", 16), ("model", 2)]),
            (16384, vec![("batch", 2), ("seq", 32), ("model", 2)]),
            (32768, vec![("batch", 2), ("seq", 32), ("model", 2)]),
        ],
    };
    let methods = [Method::Manual, Method::Alpa, Method::AutoMap, Method::Toast];
    let mut out = Vec::new();
    for (seq, axes) in points {
        // T2B dims at Bench scale shrink everything but the sequence.
        let cfg = match scale {
            BenchScale::Paper => transformer::TransformerConfig {
                seq,
                batch: 4,
                ..transformer::TransformerConfig::t2b()
            },
            _ => transformer::TransformerConfig {
                d_model: 256,
                layers: 2,
                hidden: 1024,
                heads: 8,
                key_size: 32,
                vocab: 4096,
                batch: 4,
                seq,
                training: true,
            },
        };
        let func = transformer::training_step(&cfg);
        let mesh = Mesh::grid(&axes);
        let model = CostModel::new(HardwareProfile::new(HardwareKind::A100));
        let mut rows = Vec::new();
        for method in methods {
            let r =
                run_method(method, ModelKind::T2B, &func, &mesh, &model, scale.budget(), 29);
            rows.push(GridRow::from(ModelKind::T2B, HardwareKind::A100, &r));
        }
        out.push((seq, mesh.describe(), rows));
    }
    out
}

/// Render a Fig-8-style table (step time).
pub fn format_fig8(rows: &[GridRow]) -> String {
    format_grid(
        rows,
        |r| {
            if r.oom {
                format!("{:>10}", "OOM")
            } else if r.step_ms < 0.1 {
                format!("{:>8.2}us", r.step_ms * 1e3)
            } else {
                format!("{:>8.3}ms", r.step_ms)
            }
        },
        "step time, 16 devices — Figure 8",
    )
}

/// Render a Fig-9-style table (search time).
pub fn format_fig9(rows: &[GridRow]) -> String {
    format_grid(rows, |r| format!("{:>10.2}", r.search_s), "search time (s) — Figure 9")
}

fn format_grid(
    rows: &[GridRow],
    cell: impl Fn(&GridRow) -> String,
    title: &str,
) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==");
    let methods: Vec<Method> = {
        let mut v: Vec<Method> = Vec::new();
        for r in rows {
            if !v.contains(&r.method) {
                v.push(r.method);
            }
        }
        v
    };
    let _ = write!(out, "{:<10} {:<7}", "model", "hw");
    for m in &methods {
        let _ = write!(out, " {:>10}", m.name());
    }
    let _ = writeln!(out);
    let mut seen = Vec::new();
    for r in rows {
        let key = (r.model, r.hardware);
        if seen.contains(&key) {
            continue;
        }
        seen.push(key);
        let _ = write!(out, "{:<10} {:<7}", r.model.name(), r.hardware.name());
        for m in &methods {
            if let Some(row) =
                rows.iter().find(|x| x.model == r.model && x.hardware == r.hardware && x.method == *m)
            {
                let _ = write!(out, " {}", cell(row));
            } else {
                let _ = write!(out, " {:>10}", "-");
            }
        }
        let _ = writeln!(out);
    }
    out
}

/// Render the Fig-10 table.
pub fn format_fig10(points: &[(i64, String, Vec<GridRow>)]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "== T2B sequence scaling (step ms / search s) — Figure 10 ==");
    let _ = writeln!(
        out,
        "{:<8} {:<28} {:>16} {:>16} {:>16} {:>16}",
        "seq", "mesh", "Manual", "Alpa", "AutoMap", "TOAST"
    );
    for (seq, mesh, rows) in points {
        let _ = write!(out, "{seq:<8} {mesh:<28}");
        for m in [Method::Manual, Method::Alpa, Method::AutoMap, Method::Toast] {
            if let Some(r) = rows.iter().find(|r| r.method == m) {
                let cellstr = if r.oom {
                    format!("OOM/{:.1}s", r.search_s)
                } else {
                    format!("{:.2}ms/{:.1}s", r.step_ms, r.search_s)
                };
                let _ = write!(out, " {cellstr:>16}");
            }
        }
        let _ = writeln!(out);
    }
    out
}

/// Rows → JSON array for EXPERIMENTS.md bookkeeping.
pub fn grid_json(rows: &[GridRow]) -> String {
    Json::Arr(rows.iter().map(|r| r.json()).collect()).render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_grid_runs_all_methods() {
        let rows = run_grid(
            BenchScale::Tiny,
            &[ModelKind::Mlp],
            &[HardwareKind::A100],
            &Method::all(),
        );
        assert_eq!(rows.len(), 4);
        let table = format_fig8(&rows);
        assert!(table.contains("TOAST"));
        assert!(table.contains("mlp"));
        let json = grid_json(&rows);
        assert!(json.contains("\"method\":\"TOAST\""));
    }

    #[test]
    fn seq_scaling_tiny_runs() {
        let points = run_seq_scaling(BenchScale::Tiny);
        assert_eq!(points.len(), 2);
        let table = format_fig10(&points);
        assert!(table.contains("sequence scaling"));
    }
}
