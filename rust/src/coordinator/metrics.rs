//! Service metrics: lock-free counters + latency aggregation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Aggregated service metrics. All methods are thread-safe.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub oom_solutions: AtomicU64,
    /// Total search time in microseconds (mean = total / completed).
    pub search_us_total: AtomicU64,
    /// Total state evaluations across searches.
    pub evaluations: AtomicU64,
}

impl Metrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_completion(&self, search: Duration, evals: u64, oom: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.search_us_total.fetch_add(search.as_micros() as u64, Ordering::Relaxed);
        self.evaluations.fetch_add(evals, Ordering::Relaxed);
        if oom {
            self.oom_solutions.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_failure(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn mean_search_ms(&self) -> f64 {
        let done = self.completed.load(Ordering::Relaxed);
        if done == 0 {
            return 0.0;
        }
        self.search_us_total.load(Ordering::Relaxed) as f64 / 1e3 / done as f64
    }

    pub fn snapshot(&self) -> String {
        format!(
            "requests={} completed={} failed={} oom={} mean_search={:.1}ms evals={}",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.oom_solutions.load(Ordering::Relaxed),
            self.mean_search_ms(),
            self.evaluations.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_aggregate() {
        let m = Metrics::default();
        m.record_request();
        m.record_request();
        m.record_completion(Duration::from_millis(10), 100, false);
        m.record_completion(Duration::from_millis(30), 200, true);
        m.record_failure();
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.oom_solutions.load(Ordering::Relaxed), 1);
        assert!((m.mean_search_ms() - 20.0).abs() < 0.5);
        assert!(m.snapshot().contains("completed=2"));
    }
}
