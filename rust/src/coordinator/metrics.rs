//! Service metrics: lock-free counters + latency aggregation.
//!
//! Both transports account through the same two choke points so the
//! counters cannot drift between modes: [`Metrics::record_dispatch`]
//! when a request leaves the queue for a worker (thread or socket), and
//! [`Metrics::record_response`] when the worker's response is received.
//! [`Metrics::report`] flattens everything into the serializable
//! [`StatusReport`] a `status` request returns over the wire.
//!
//! Latency lives in lock-free log-bucketed [`Histogram`]s (one per
//! request phase: queue wait, cold search, cache-hit answer, verify),
//! so the running service reports *true* p50/p99 — not an average —
//! both as [`LatencySummary`] rows in the status report and as
//! Prometheus text exposition via [`Metrics::prometheus_text`]
//! (`toast status --prom`, or the `metrics` wire request).

use crate::api::wire::{LatencySummary, StatusReport};
use crate::api::PartitionResponse;
use crate::obs::Histogram;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Aggregated service metrics. All methods are thread-safe.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub oom_solutions: AtomicU64,
    /// Requests accepted but not yet picked up by a worker (queue depth).
    pub queued: AtomicU64,
    /// Requests dispatched to a worker whose response has not arrived.
    pub in_flight: AtomicU64,
    /// In-flight requests put back on the queue after their worker died
    /// (socket transport: heartbeat timeout, EOF, or a write failure).
    pub requeued: AtomicU64,
    /// Workers currently attached: in-process threads plus registered
    /// socket workers that are still alive.
    pub workers: AtomicU64,
    /// Solutions that passed the trust-but-verify differential replay.
    pub verified: AtomicU64,
    /// Solutions *rejected* by the verify gate (spec diverged from the
    /// interpreter oracle — returned as failures, never trusted).
    pub rejected: AtomicU64,
    /// Total search time in microseconds (mean = total / completed).
    pub search_us_total: AtomicU64,
    /// Total state evaluations across searches.
    pub evaluations: AtomicU64,
    /// Submits answered from the solution cache without a dispatch.
    pub cache_hits: AtomicU64,
    /// Submits that missed the cache (and went on to the queue).
    pub cache_misses: AtomicU64,
    /// Solutions currently held by the cache (gauge).
    pub cache_size: AtomicU64,
    /// Worker results sampled for server-side differential replay.
    pub audited: AtomicU64,
    /// Audited results whose claimed validation record could not be
    /// reproduced (forged or wrong — converted to rejections).
    pub audit_rejected: AtomicU64,
    /// Submits refused by admission control (queue at its bound).
    pub overloaded: AtomicU64,
    /// Time a request sat between admission and dispatch, microseconds.
    pub hist_queue_wait: Histogram,
    /// Full search latency for cache-miss ("cold") requests.
    pub hist_search_cold: Histogram,
    /// Admission-to-answer latency for cache-hit requests.
    pub hist_cache_hit: Histogram,
    /// Differential verify / server audit replay latency.
    pub hist_verify: Histogram,
}

/// Saturating decrement: gauges must never underflow into u64::MAX even
/// if an accounting bug unbalances an inc/dec pair.
fn sat_dec(gauge: &AtomicU64) {
    let _ = gauge.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |g| {
        Some(g.saturating_sub(1))
    });
}

impl Metrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// A request is about to enter the queue. Called *before* the push so
    /// a fast worker's matching [`Metrics::record_dispatch`] can never
    /// observe the queue gauge at 0 and leave it permanently inflated.
    pub fn record_enqueue(&self) {
        self.queued.fetch_add(1, Ordering::Relaxed);
    }

    /// Undo of [`Metrics::record_enqueue`] for a submit that failed
    /// before the request ever reached the queue.
    pub fn record_unqueue(&self) {
        sat_dec(&self.queued);
    }

    /// A worker (thread or socket) took a request off the queue.
    pub fn record_dispatch(&self) {
        sat_dec(&self.queued);
        self.in_flight.fetch_add(1, Ordering::Relaxed);
    }

    /// A dispatched request went back on the queue because its worker
    /// died before answering.
    pub fn record_requeue(&self) {
        self.requeued.fetch_add(1, Ordering::Relaxed);
        sat_dec(&self.in_flight);
        self.queued.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_worker_connected(&self) {
        self.workers.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_worker_lost(&self) {
        sat_dec(&self.workers);
    }

    /// Requests accepted but not yet picked up by a worker.
    pub fn queue_depth(&self) -> u64 {
        self.queued.load(Ordering::Relaxed)
    }

    /// The single response-side accounting path, shared by the
    /// in-process worker threads and the socket server: completion or
    /// failure, verification verdicts, search time and evaluation
    /// throughput all come off the response itself, so a worker process
    /// needs no metrics channel of its own.
    pub fn record_response(&self, resp: &PartitionResponse) {
        sat_dec(&self.in_flight);
        match &resp.result {
            Ok(sol) => {
                self.record_completion(
                    Duration::from_secs_f64(sol.search_time_s),
                    sol.evals as u64,
                    sol.oom,
                );
                if sol.validation.as_ref().is_some_and(|v| v.pass) {
                    self.record_verified();
                }
            }
            Err(_) => {
                self.record_failure();
                if resp.rejected {
                    self.record_rejected();
                }
            }
        }
    }

    pub fn record_completion(&self, search: Duration, evals: u64, oom: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.search_us_total.fetch_add(search.as_micros() as u64, Ordering::Relaxed);
        self.evaluations.fetch_add(evals, Ordering::Relaxed);
        if oom {
            self.oom_solutions.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_failure(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_verified(&self) {
        self.verified.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// A submit was answered straight from the solution cache. Cache hits
    /// never touch the queue/in-flight gauges (nothing was dispatched),
    /// so this is deliberately *not* [`Metrics::record_response`]: it
    /// counts the request, the completion, and the verification verdict
    /// carried by the cached artifact.
    pub fn record_cache_hit(&self, resp: &PartitionResponse) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
        self.record_request();
        if let Ok(sol) = &resp.result {
            self.completed.fetch_add(1, Ordering::Relaxed);
            if sol.oom {
                self.oom_solutions.fetch_add(1, Ordering::Relaxed);
            }
            if sol.validation.as_ref().is_some_and(|v| v.pass) {
                self.record_verified();
            }
        }
    }

    pub fn record_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn set_cache_size(&self, size: u64) {
        self.cache_size.store(size, Ordering::Relaxed);
    }

    pub fn record_audited(&self) {
        self.audited.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_audit_rejected(&self) {
        self.audit_rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_overloaded(&self) {
        self.overloaded.fetch_add(1, Ordering::Relaxed);
    }

    /// Admission-to-dispatch wait for one request.
    pub fn record_queue_wait(&self, wait: Duration) {
        self.hist_queue_wait.record(wait.as_micros() as u64);
    }

    /// Full search latency for a cache-miss request.
    pub fn record_search_latency(&self, search: Duration) {
        self.hist_search_cold.record(search.as_micros() as u64);
    }

    /// Admission-to-answer latency for a cache-hit request.
    pub fn record_cache_hit_latency(&self, latency: Duration) {
        self.hist_cache_hit.record(latency.as_micros() as u64);
    }

    /// One differential verify (or server-side audit) replay.
    pub fn record_verify_latency(&self, verify: Duration) {
        self.hist_verify.record(verify.as_micros() as u64);
    }

    /// Per-phase latency digests for the status report: one row per
    /// phase that has recorded at least one sample.
    pub fn latency_summaries(&self) -> Vec<LatencySummary> {
        let phases: [(&str, &Histogram); 4] = [
            ("queue_wait", &self.hist_queue_wait),
            ("search_cold", &self.hist_search_cold),
            ("cache_hit", &self.hist_cache_hit),
            ("verify", &self.hist_verify),
        ];
        phases
            .into_iter()
            .filter_map(|(phase, hist)| {
                let snap = hist.snapshot();
                (snap.count > 0).then(|| LatencySummary {
                    phase: phase.to_string(),
                    count: snap.count,
                    p50_us: snap.quantile(0.5),
                    p99_us: snap.quantile(0.99),
                })
            })
            .collect()
    }

    /// Prometheus text exposition: every counter/gauge as a
    /// `toast_*`-prefixed metric plus the per-phase latency histograms
    /// as cumulative `_bucket`/`_sum`/`_count` series under one family
    /// (`toast_request_latency_us{phase=...}`). Serve verbatim to a
    /// scrape (text format 0.0.4).
    pub fn prometheus_text(&self) -> String {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        let mut out = String::new();
        let counters: [(&str, u64); 14] = [
            ("toast_requests_total", g(&self.requests)),
            ("toast_completed_total", g(&self.completed)),
            ("toast_failed_total", g(&self.failed)),
            ("toast_verified_total", g(&self.verified)),
            ("toast_rejected_total", g(&self.rejected)),
            ("toast_requeued_total", g(&self.requeued)),
            ("toast_evaluations_total", g(&self.evaluations)),
            ("toast_cache_hits_total", g(&self.cache_hits)),
            ("toast_cache_misses_total", g(&self.cache_misses)),
            ("toast_audited_total", g(&self.audited)),
            ("toast_audit_rejected_total", g(&self.audit_rejected)),
            ("toast_overloaded_total", g(&self.overloaded)),
            ("toast_oom_solutions_total", g(&self.oom_solutions)),
            ("toast_search_us_total", g(&self.search_us_total)),
        ];
        for (name, v) in counters {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        }
        let gauges: [(&str, u64); 4] = [
            ("toast_queue_depth", g(&self.queued)),
            ("toast_in_flight", g(&self.in_flight)),
            ("toast_workers", g(&self.workers)),
            ("toast_cache_size", g(&self.cache_size)),
        ];
        for (name, v) in gauges {
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {v}");
        }
        let _ = writeln!(out, "# TYPE toast_request_latency_us histogram");
        let phases: [(&str, &Histogram); 4] = [
            ("queue_wait", &self.hist_queue_wait),
            ("search_cold", &self.hist_search_cold),
            ("cache_hit", &self.hist_cache_hit),
            ("verify", &self.hist_verify),
        ];
        for (phase, hist) in phases {
            hist.snapshot().render_prometheus(
                "toast_request_latency_us",
                &format!("phase=\"{phase}\""),
                &mut out,
            );
        }
        out
    }

    pub fn mean_search_ms(&self) -> f64 {
        let done = self.completed.load(Ordering::Relaxed);
        if done == 0 {
            return 0.0;
        }
        self.search_us_total.load(Ordering::Relaxed) as f64 / 1e3 / done as f64
    }

    /// The serializable counter snapshot a `status` request answers with.
    pub fn report(&self) -> StatusReport {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        StatusReport {
            requests: g(&self.requests),
            queued: g(&self.queued),
            in_flight: g(&self.in_flight),
            completed: g(&self.completed),
            failed: g(&self.failed),
            verified: g(&self.verified),
            rejected: g(&self.rejected),
            requeued: g(&self.requeued),
            workers: g(&self.workers),
            evaluations: g(&self.evaluations),
            cache_hits: g(&self.cache_hits),
            cache_misses: g(&self.cache_misses),
            cache_size: g(&self.cache_size),
            audited: g(&self.audited),
            audit_rejected: g(&self.audit_rejected),
            overloaded: g(&self.overloaded),
            oom_solutions: g(&self.oom_solutions),
            search_us_total: g(&self.search_us_total),
            // Per-worker rows need the worker registry, which lives on
            // the service — `ServiceShared::status_report` fills them.
            workers_detail: Vec::new(),
            latency: self.latency_summaries(),
        }
    }

    pub fn snapshot(&self) -> String {
        format!(
            "requests={} queued={} in_flight={} completed={} failed={} verified={} \
             rejected={} requeued={} workers={} oom={} mean_search={:.1}ms evals={} \
             cache_hits={} cache_misses={} cache_size={} audited={} audit_rejected={} \
             overloaded={}",
            self.requests.load(Ordering::Relaxed),
            self.queue_depth(),
            self.in_flight.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.verified.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.requeued.load(Ordering::Relaxed),
            self.workers.load(Ordering::Relaxed),
            self.oom_solutions.load(Ordering::Relaxed),
            self.mean_search_ms(),
            self.evaluations.load(Ordering::Relaxed),
            self.cache_hits.load(Ordering::Relaxed),
            self.cache_misses.load(Ordering::Relaxed),
            self.cache_size.load(Ordering::Relaxed),
            self.audited.load(Ordering::Relaxed),
            self.audit_rejected.load(Ordering::Relaxed),
            self.overloaded.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_aggregate() {
        let m = Metrics::default();
        m.record_enqueue();
        m.record_request();
        m.record_enqueue();
        m.record_request();
        assert_eq!(m.queue_depth(), 2);
        m.record_dispatch();
        m.record_dispatch();
        assert_eq!(m.queue_depth(), 0);
        assert_eq!(m.in_flight.load(Ordering::Relaxed), 2);
        m.record_completion(Duration::from_millis(10), 100, false);
        m.record_completion(Duration::from_millis(30), 200, true);
        m.record_failure();
        m.record_verified();
        m.record_rejected();
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.oom_solutions.load(Ordering::Relaxed), 1);
        assert!((m.mean_search_ms() - 20.0).abs() < 0.5);
        assert!(m.snapshot().contains("completed=2"));
        assert!(m.snapshot().contains("queued=0"));
        assert!(m.snapshot().contains("verified=1"));
    }

    #[test]
    fn requeue_moves_a_request_from_in_flight_back_to_the_queue() {
        let m = Metrics::default();
        m.record_enqueue();
        m.record_request();
        m.record_dispatch();
        assert_eq!(m.queue_depth(), 0);
        m.record_requeue();
        assert_eq!(m.queue_depth(), 1);
        assert_eq!(m.in_flight.load(Ordering::Relaxed), 0);
        assert_eq!(m.requeued.load(Ordering::Relaxed), 1);
        let report = m.report();
        assert_eq!(report.requeued, 1);
        assert_eq!(report.queued, 1);
        assert_eq!(report.requests, 1);
    }

    #[test]
    fn worker_gauge_tracks_connections() {
        let m = Metrics::default();
        m.record_worker_connected();
        m.record_worker_connected();
        m.record_worker_lost();
        assert_eq!(m.report().workers, 1);
        m.record_worker_lost();
        m.record_worker_lost(); // saturates at 0
        assert_eq!(m.report().workers, 0);
    }

    #[test]
    fn gauges_never_underflow() {
        let m = Metrics::default();
        m.record_dispatch();
        m.record_unqueue();
        assert_eq!(m.queue_depth(), 0);
        assert_eq!(m.in_flight.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn throughput_counters_flow_into_report_and_snapshot() {
        let m = Metrics::default();
        m.record_cache_miss();
        m.record_cache_miss();
        m.set_cache_size(1);
        m.record_audited();
        m.record_audit_rejected();
        m.record_overloaded();
        let r = m.report();
        assert_eq!(r.cache_hits, 0);
        assert_eq!(r.cache_misses, 2);
        assert_eq!(r.cache_size, 1);
        assert_eq!(r.audited, 1);
        assert_eq!(r.audit_rejected, 1);
        assert_eq!(r.overloaded, 1);
        let snap = m.snapshot();
        assert!(snap.contains("cache_misses=2"));
        assert!(snap.contains("audit_rejected=1"));
        assert!(snap.contains("overloaded=1"));
        // A cache hit counts the request and completion but leaves the
        // queue/in-flight gauges alone: nothing was ever dispatched.
        assert_eq!(m.queue_depth(), 0);
        assert_eq!(m.in_flight.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn oom_and_search_time_flow_into_the_wire_report() {
        let m = Metrics::default();
        m.record_completion(Duration::from_millis(10), 100, true);
        m.record_completion(Duration::from_millis(30), 200, false);
        let r = m.report();
        assert_eq!(r.oom_solutions, 1);
        assert_eq!(r.search_us_total, 40_000);
        // The wire report and the human snapshot can no longer drift:
        // both carry the OOM count and the search-time total.
        assert!(m.snapshot().contains("oom=1"));
        assert!(r.render_line().contains("oom_solutions=1"));
        assert!(r.render_line().contains("search_us_total=40000"));
    }

    #[test]
    fn latency_histograms_summarize_and_expose() {
        let m = Metrics::default();
        assert!(m.latency_summaries().is_empty(), "no samples, no rows");
        m.record_queue_wait(Duration::from_micros(100));
        m.record_search_latency(Duration::from_millis(20));
        m.record_search_latency(Duration::from_millis(21));
        m.record_cache_hit_latency(Duration::from_micros(40));
        m.record_verify_latency(Duration::from_millis(3));
        let rows = m.latency_summaries();
        assert_eq!(rows.len(), 4);
        let cold = rows.iter().find(|r| r.phase == "search_cold").unwrap();
        assert_eq!(cold.count, 2);
        assert!(cold.p50_us >= 16_384 && cold.p50_us <= 65_535, "{cold:?}");
        assert!(cold.p99_us >= cold.p50_us, "{cold:?}");
        let report = m.report();
        assert_eq!(report.latency, rows);

        m.record_request();
        let prom = m.prometheus_text();
        assert!(prom.contains("# TYPE toast_requests_total counter"), "{prom}");
        assert!(prom.contains("toast_requests_total 1"), "{prom}");
        assert!(prom.contains("# TYPE toast_request_latency_us histogram"), "{prom}");
        assert!(
            prom.contains("toast_request_latency_us_bucket{phase=\"search_cold\",le="),
            "{prom}"
        );
        assert!(
            prom.contains("toast_request_latency_us_bucket{phase=\"cache_hit\",le=\"+Inf\"} 1"),
            "{prom}"
        );
        assert!(prom.contains("toast_request_latency_us_count{phase=\"verify\"} 1"), "{prom}");
    }
}
