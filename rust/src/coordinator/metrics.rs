//! Service metrics: lock-free counters + latency aggregation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Aggregated service metrics. All methods are thread-safe.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub oom_solutions: AtomicU64,
    /// Requests accepted but not yet picked up by a worker (queue depth).
    pub queued: AtomicU64,
    /// Solutions that passed the trust-but-verify differential replay.
    pub verified: AtomicU64,
    /// Solutions *rejected* by the verify gate (spec diverged from the
    /// interpreter oracle — returned as failures, never trusted).
    pub rejected: AtomicU64,
    /// Total search time in microseconds (mean = total / completed).
    pub search_us_total: AtomicU64,
    /// Total state evaluations across searches.
    pub evaluations: AtomicU64,
}

impl Metrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// A request is about to enter the queue. Called *before* the send so
    /// a fast worker's matching [`Metrics::record_dequeue`] can never
    /// observe the queue gauge at 0 and leave it permanently inflated.
    pub fn record_enqueue(&self) {
        self.queued.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker picked a request off the queue.
    pub fn record_dequeue(&self) {
        // Saturating: a dequeue without a matching enqueue is a bug, but
        // metrics must never underflow into u64::MAX.
        let _ = self.queued.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |q| {
            Some(q.saturating_sub(1))
        });
    }

    /// Requests accepted but not yet picked up by a worker.
    pub fn queue_depth(&self) -> u64 {
        self.queued.load(Ordering::Relaxed)
    }

    pub fn record_completion(&self, search: Duration, evals: u64, oom: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.search_us_total.fetch_add(search.as_micros() as u64, Ordering::Relaxed);
        self.evaluations.fetch_add(evals, Ordering::Relaxed);
        if oom {
            self.oom_solutions.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_failure(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_verified(&self) {
        self.verified.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn mean_search_ms(&self) -> f64 {
        let done = self.completed.load(Ordering::Relaxed);
        if done == 0 {
            return 0.0;
        }
        self.search_us_total.load(Ordering::Relaxed) as f64 / 1e3 / done as f64
    }

    pub fn snapshot(&self) -> String {
        format!(
            "requests={} queued={} completed={} failed={} verified={} rejected={} oom={} \
             mean_search={:.1}ms evals={}",
            self.requests.load(Ordering::Relaxed),
            self.queue_depth(),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.verified.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.oom_solutions.load(Ordering::Relaxed),
            self.mean_search_ms(),
            self.evaluations.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_aggregate() {
        let m = Metrics::default();
        m.record_enqueue();
        m.record_request();
        m.record_enqueue();
        m.record_request();
        assert_eq!(m.queue_depth(), 2);
        m.record_dequeue();
        m.record_dequeue();
        assert_eq!(m.queue_depth(), 0);
        m.record_completion(Duration::from_millis(10), 100, false);
        m.record_completion(Duration::from_millis(30), 200, true);
        m.record_failure();
        m.record_verified();
        m.record_rejected();
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.oom_solutions.load(Ordering::Relaxed), 1);
        assert!((m.mean_search_ms() - 20.0).abs() < 0.5);
        assert!(m.snapshot().contains("completed=2"));
        assert!(m.snapshot().contains("queued=0"));
        assert!(m.snapshot().contains("verified=1"));
    }

    #[test]
    fn queue_depth_never_underflows() {
        let m = Metrics::default();
        m.record_dequeue();
        assert_eq!(m.queue_depth(), 0);
    }
}
