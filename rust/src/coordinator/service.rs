//! The partition service: a request queue with a worker-thread pool,
//! rebuilt on the session API.
//!
//! Requests are *model-agnostic*: they carry a [`ModelSource`] — a zoo
//! name the workers rebuild, or a fully serialized `Func` for models the
//! service has never seen. Workers resolve each source to a shared
//! [`CompiledModel`] (one NDA per distinct model, cached across requests
//! and threads), run the requested strategy through the one
//! [`crate::api::Strategy`] signature, and return a serializable
//! [`Solution`].
//!
//! **Trust but verify**: before a solution is accepted, the service
//! replays its spec through [`crate::runtime::diff::differential_test`]
//! against the interpreter oracle. A diverging spec is *rejected* —
//! returned as a failure and counted in
//! [`super::metrics::Metrics::rejected`] — so no caller ever receives an
//! unverified sharding claim. (Paper-scale IR is exempt: executing it
//! numerically would take hours; the exemption is recorded by the
//! absence of a validation record on the solution.)

use super::metrics::Metrics;
use crate::api::{validate_solution_spec, CompiledModel, ModelSource, Solution};
use crate::baselines::Method;
use crate::mesh::{HardwareKind, Mesh};
use crate::models::ModelKind;
use crate::util::json::Json;
use anyhow::anyhow;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A partitioning request.
#[derive(Clone, Debug)]
pub struct PartitionRequest {
    pub id: u64,
    /// The model to partition: zoo reference or inline IR.
    pub model: ModelSource,
    pub mesh: Mesh,
    pub hardware: HardwareKind,
    pub method: Method,
    /// Search budget (state evaluations).
    pub budget: usize,
    pub seed: u64,
    /// Opt out of the trust-but-verify replay for this request (the
    /// service may still skip it for paper-scale models).
    pub verify: bool,
}

impl PartitionRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", crate::api::wire::u64_to_json(self.id)),
            ("model", self.model.to_json()),
            ("mesh", self.mesh.to_json()),
            ("hardware", Json::s(self.hardware.name())),
            ("method", Json::s(self.method.name())),
            ("budget", Json::n(self.budget as f64)),
            ("seed", crate::api::wire::u64_to_json(self.seed)),
            ("verify", Json::Bool(self.verify)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<PartitionRequest> {
        use crate::api::wire;
        let ctx = "partition request";
        Ok(PartitionRequest {
            id: wire::u64_field(j, "id", ctx)?,
            model: ModelSource::from_json(wire::field(j, "model", ctx)?)?,
            mesh: Mesh::from_json(wire::field(j, "mesh", ctx)?)?,
            hardware: wire::str_field(j, "hardware", ctx)?
                .parse()
                .map_err(|e: String| anyhow!(e))?,
            method: wire::str_field(j, "method", ctx)?
                .parse()
                .map_err(|e: String| anyhow!(e))?,
            budget: wire::usize_field(j, "budget", ctx)?,
            seed: wire::u64_field(j, "seed", ctx)?,
            verify: wire::bool_field(j, "verify", ctx)?,
        })
    }
}

/// A completed partitioning job.
pub struct PartitionResponse {
    pub id: u64,
    pub request: PartitionRequest,
    pub result: anyhow::Result<Solution>,
}

impl PartitionResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", crate::api::wire::u64_to_json(self.id)),
            ("request", self.request.to_json()),
            (
                "result",
                match &self.result {
                    Ok(sol) => Json::obj(vec![("ok", sol.to_json())]),
                    Err(e) => Json::obj(vec![("err", Json::s(format!("{e:#}")))]),
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<PartitionResponse> {
        use crate::api::wire;
        let ctx = "partition response";
        let request = PartitionRequest::from_json(wire::field(j, "request", ctx)?)?;
        let rj = wire::field(j, "result", ctx)?;
        let result = if let Some(ok) = rj.get("ok") {
            Ok(Solution::from_json(ok)?)
        } else if let Some(err) = rj.get("err") {
            Err(anyhow!(err
                .as_str()
                .ok_or_else(|| anyhow!("{ctx}: 'err' is not a string"))?
                .to_string()))
        } else {
            anyhow::bail!("{ctx}: result needs 'ok' or 'err'");
        };
        Ok(PartitionResponse { id: wire::u64_field(j, "id", ctx)?, request, result })
    }
}

/// Service tuning knobs.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub workers: usize,
    /// Master switch for the trust-but-verify gate (per-request `verify`
    /// can only opt *out*, never force verification of paper-scale IR).
    pub verify: bool,
    /// Input seed used for verification replays.
    pub verify_seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { workers: 4, verify: true, verify_seed: 7 }
    }
}

/// Cache of compiled zoo models, shared by all workers: the NDA and
/// action spaces for a given model are built once per service lifetime,
/// not once per request. The map lock is only held to look up or insert
/// the per-model cell; the (possibly expensive) compile runs inside the
/// cell's `OnceLock`, so workers serving other, already-cached models
/// never wait behind it. Errors are cached as strings (a zoo model that
/// fails to compile will fail identically every time).
type ModelCell = Arc<std::sync::OnceLock<Result<Arc<CompiledModel>, String>>>;
type ModelCache = Mutex<HashMap<(ModelKind, bool), ModelCell>>;

/// The running service.
pub struct Service {
    tx: Sender<PartitionRequest>,
    pub responses: Receiver<PartitionResponse>,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Service {
    /// Spawn a service with `n_workers` worker threads and default
    /// verification settings.
    pub fn start(n_workers: usize) -> Service {
        Self::start_with(ServiceConfig { workers: n_workers, ..Default::default() })
    }

    /// Spawn a service with explicit configuration.
    pub fn start_with(cfg: ServiceConfig) -> Service {
        let (tx, rx) = channel::<PartitionRequest>();
        let rx = Arc::new(Mutex::new(rx));
        let (resp_tx, responses) = channel::<PartitionResponse>();
        let metrics = Arc::new(Metrics::default());
        let models: Arc<ModelCache> = Arc::new(Mutex::new(HashMap::new()));
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let resp_tx = resp_tx.clone();
            let metrics = Arc::clone(&metrics);
            let models = Arc::clone(&models);
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || loop {
                let req = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok(req) = req else { break };
                metrics.record_dequeue();
                let result = handle(&req, &models, &cfg, &metrics);
                match &result {
                    Ok(sol) => metrics.record_completion(
                        std::time::Duration::from_secs_f64(sol.search_time_s),
                        sol.evals as u64,
                        sol.oom,
                    ),
                    Err(_) => metrics.record_failure(),
                }
                if resp_tx.send(PartitionResponse { id: req.id, request: req, result }).is_err()
                {
                    break;
                }
            }));
        }
        Service { tx, responses, metrics, workers, next_id: AtomicU64::new(1) }
    }

    /// Submit a request; returns its id, or an error if the service has
    /// shut down (workers gone / queue closed) — submission after
    /// shutdown is a caller error, not a panic.
    pub fn submit(&self, mut req: PartitionRequest) -> crate::Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        req.id = id;
        // Enqueue gauge goes up *before* the send: once the request is in
        // the channel a worker may dequeue it immediately, and its
        // decrement must always pair with this increment.
        self.metrics.record_enqueue();
        if self.tx.send(req).is_err() {
            self.metrics.record_dequeue();
            return Err(anyhow!("partition service is shut down; request {id} dropped"));
        }
        self.metrics.record_request();
        Ok(id)
    }

    /// Shut down: close the queue and join workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Resolve a request's model source to a compiled model. Zoo models are
/// compiled once and shared across requests and workers; inline models
/// are compiled per request (the service has no identity to key them
/// on).
fn compiled_for(
    source: &ModelSource,
    models: &ModelCache,
) -> crate::Result<Arc<CompiledModel>> {
    match source {
        ModelSource::Zoo { kind, paper_scale } => {
            let cell: ModelCell = {
                let mut cache = models.lock().unwrap();
                Arc::clone(cache.entry((*kind, *paper_scale)).or_default())
            };
            // Two workers racing on the same *uncompiled* model: one
            // compiles, the other blocks on the cell — never a duplicate
            // NDA run, and never the map lock held across a compile.
            let result = cell.get_or_init(|| {
                CompiledModel::from_kind(*kind, *paper_scale)
                    .map(Arc::new)
                    .map_err(|e| format!("{e:#}"))
            });
            result.clone().map_err(|e| anyhow!(e))
        }
        ModelSource::Inline(f) => Ok(Arc::new(CompiledModel::compile(f.clone())?)),
    }
}

fn handle(
    req: &PartitionRequest,
    models: &ModelCache,
    cfg: &ServiceConfig,
    metrics: &Metrics,
) -> crate::Result<Solution> {
    let compiled = compiled_for(&req.model, models)?;
    let mut sol = compiled
        .partition(&req.mesh)
        .method(req.method)
        .hardware(req.hardware)
        .budget(req.budget)
        .seed(req.seed)
        .run()?;
    // Trust-but-verify: replay the returned spec through the
    // differential harness before accepting it. The strategy's own
    // claims (cost, spec) are not trusted until the executed sharded
    // module matches the interpreter oracle.
    if cfg.verify && req.verify && compiled.interpreter_sized() {
        match validate_solution_spec(compiled.func(), &sol.spec, &req.mesh, cfg.verify_seed) {
            Ok(record) if record.pass => {
                metrics.record_verified();
                sol.validation = Some(record);
            }
            Ok(record) => {
                metrics.record_rejected();
                anyhow::bail!(
                    "spec rejected by the verification gate: max relative divergence {:.3e} \
                     exceeds tol {:.1e} (strategy {})",
                    record.max_rel_err,
                    record.tol,
                    sol.strategy
                );
            }
            // A replay that cannot even run (spec fails the structural
            // check, partitioning or execution errors) is just as
            // untrustworthy as a diverging one — count it as rejected.
            Err(e) => {
                metrics.record_rejected();
                return Err(e.context(format!(
                    "spec rejected by the verification gate: replay failed (strategy {})",
                    sol.strategy
                )));
            }
        }
    }
    Ok(sol)
}

/// Convenience default request (scaled zoo model, 2x2 mesh, A100).
pub fn default_request(model: ModelKind, method: Method) -> PartitionRequest {
    PartitionRequest {
        id: 0,
        model: ModelSource::zoo(model),
        mesh: Mesh::grid(&[("data", 2), ("model", 2)]),
        hardware: HardwareKind::A100,
        method,
        budget: 150,
        seed: 0,
        verify: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_processes_requests() {
        let svc = Service::start(2);
        let mut ids = Vec::new();
        for method in [Method::Toast, Method::Manual] {
            ids.push(svc.submit(default_request(ModelKind::Mlp, method)).unwrap());
        }
        let mut got = Vec::new();
        for _ in 0..ids.len() {
            let resp = svc.responses.recv().expect("response");
            let sol = resp.result.as_ref().expect("job succeeds");
            // trust-but-verify ran and passed
            let v = sol.validation.as_ref().expect("verification record");
            assert!(v.pass);
            got.push(resp.id);
        }
        got.sort_unstable();
        assert_eq!(got, ids);
        let snap = svc.metrics.snapshot();
        assert!(snap.contains("completed=2"), "{snap}");
        assert!(snap.contains("verified=2"), "{snap}");
        assert!(snap.contains("queued=0"), "{snap}");
        svc.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors_instead_of_panicking() {
        // A service whose queue receiver is gone behaves exactly like one
        // whose workers all died: submit must surface an Err, not panic.
        let (tx, rx) = channel::<PartitionRequest>();
        drop(rx);
        let svc = Service {
            tx,
            responses: channel::<PartitionResponse>().1,
            metrics: Arc::new(Metrics::default()),
            workers: Vec::new(),
            next_id: AtomicU64::new(1),
        };
        let err = svc.submit(default_request(ModelKind::Mlp, Method::Manual));
        assert!(err.is_err(), "submit after worker death must be an Err, not a panic");
        assert_eq!(svc.metrics.queue_depth(), 0, "failed submits are not queued");
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn inline_models_are_served() {
        let mut b = crate::ir::FuncBuilder::new("inline_mlp");
        let x = b.param("x", crate::ir::TensorType::f32(vec![16, 8]));
        let w = b.param("w", crate::ir::TensorType::f32(vec![8, 4]));
        let y = b.matmul(x, w);
        let func = b.build(vec![y]);
        let svc = Service::start(1);
        let mut req = default_request(ModelKind::Mlp, Method::Toast);
        req.model = ModelSource::Inline(func);
        req.budget = 40;
        svc.submit(req).unwrap();
        let resp = svc.responses.recv().unwrap();
        let sol = resp.result.expect("inline job succeeds");
        assert!(sol.validation.expect("verified").pass);
        assert!(matches!(sol.model, ModelSource::Inline(_)));
        svc.shutdown();
    }

    #[test]
    fn request_and_response_roundtrip_json() {
        let req = default_request(ModelKind::Attention, Method::Alpa);
        let jr = Json::parse(&req.to_json().render()).unwrap();
        let back = PartitionRequest::from_json(&jr).unwrap();
        assert_eq!(back.model, req.model);
        assert_eq!(back.mesh, req.mesh);
        assert_eq!(back.method, req.method);
        assert_eq!(back.hardware, req.hardware);
        assert_eq!(back.budget, req.budget);
        assert_eq!(back.verify, req.verify);

        // An error response survives the wire too.
        let resp = PartitionResponse {
            id: 9,
            request: req,
            result: Err(anyhow!("strategy exploded")),
        };
        let jr = Json::parse(&resp.to_json().render()).unwrap();
        let back = PartitionResponse::from_json(&jr).unwrap();
        assert_eq!(back.id, 9);
        assert!(back.result.is_err());
        assert!(format!("{:#}", back.result.unwrap_err()).contains("strategy exploded"));
    }
}
