//! The partition service: queue + dispatch, rebuilt so the in-process
//! thread mode and the socket mode ([`super::transport`]) share one code
//! path.
//!
//! Requests are *model-agnostic*: they carry a [`ModelSource`] — a zoo
//! name the workers rebuild, or a fully serialized `Func` for models the
//! service has never seen. Workers resolve each source to a shared
//! [`CompiledModel`] through a [`ModelCache`] (one NDA per distinct
//! model, cached across requests and threads), run the requested
//! strategy through the one [`crate::api::Strategy`] signature, and
//! return a serializable [`PartitionResponse`].
//!
//! **One dispatch/verify path for both transports.** Jobs flow through
//! the [`JobQueue`] whether a worker is a thread in this process or a
//! `toast worker` process on the other end of a TCP socket; every worker
//! — local or remote — executes [`process_request`] (compiled-model
//! cache + strategy + trust-but-verify replay), and every response is
//! accounted through [`Metrics::record_response`]. The transports differ
//! only in how bytes move, so they cannot drift semantically.
//!
//! **Trust but verify**: before a solution is accepted, the worker
//! replays its spec through [`crate::runtime::diff::differential_test`]
//! against the interpreter oracle. A diverging spec is *rejected* —
//! returned as a failure, flagged on the response, and counted in
//! [`Metrics::rejected`] — so no caller ever receives an unverified
//! sharding claim. (Paper-scale IR is exempt: executing it numerically
//! would take hours; the exemption is recorded by the absence of a
//! validation record on the solution.)

use super::metrics::Metrics;
use crate::api::wire::{StatusReport, WorkerDetail};
use crate::api::{validate_solution_spec, CompiledModel, MctsStrategy, ModelSource, Solution};
use crate::baselines::Method;
use crate::mesh::{HardwareKind, Mesh, Topology};
use crate::models::ModelKind;
use crate::obs;
use crate::search::SearchConfig;
use anyhow::anyhow;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// The request/response job unit lives in the API layer (it is a wire
// artifact); re-exported here so `coordinator::PartitionRequest` keeps
// working for existing callers.
pub use crate::api::{PartitionRequest, PartitionResponse};

/// Service tuning knobs.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// In-process worker threads. `0` is meaningful: a transport-only
    /// service whose workers all arrive over a socket.
    pub workers: usize,
    /// Master switch for the trust-but-verify gate (per-request `verify`
    /// can only opt *out*, never force verification of paper-scale IR).
    pub verify: bool,
    /// Input seed used for verification replays.
    pub verify_seed: u64,
    /// Worker threads *inside* one MCTS search (`0` = library default).
    /// Set to 1 for bit-reproducible solutions: parallel rollouts race
    /// benignly on the tree, so only single-threaded searches are
    /// deterministic for a fixed seed — which is what lets CI diff the
    /// thread mode against the socket mode byte for byte.
    pub search_threads: usize,
    /// Solution-cache capacity in entries (`0` disables the cache).
    /// Repeated requests for the same (model, mesh, topology, method,
    /// budget, seed) are answered from the cache without a dispatch.
    pub cache_capacity: usize,
    /// Admission bound: submits are refused with [`Overloaded`] while
    /// the queue holds this many requests (`0` = unbounded).
    pub max_queue: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 4,
            verify: true,
            verify_seed: 7,
            search_threads: 0,
            cache_capacity: 128,
            max_queue: 0,
        }
    }
}

/// Structured admission-control refusal: the queue sits at its bound.
/// Carried through `anyhow` so both transports can downcast and answer
/// with the wire-level `overloaded` message instead of a plain error.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Overloaded {
    /// Queue depth observed at refusal time.
    pub queued: u64,
    /// The configured bound ([`ServiceConfig::max_queue`]).
    pub limit: u64,
}

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "service overloaded: {} requests queued (admission bound {}); retry later",
            self.queued, self.limit
        )
    }
}

impl std::error::Error for Overloaded {}

// ---------------------------------------------------------------------------
// JobQueue — the dispatch queue both transports pull from
// ---------------------------------------------------------------------------

/// Outcome of a timed queue pop.
// The job variant dwarfs the control variants; `Popped` values are
// consumed immediately, so boxing the request would buy nothing.
#[allow(clippy::large_enum_variant)]
pub enum Popped {
    Job(PartitionRequest),
    /// Timeout elapsed with nothing queued (poll again; used by socket
    /// feeders that must interleave liveness checks with dispatch).
    Empty,
    /// The queue is closed and drained — the service is shutting down.
    Closed,
}

/// An unbounded MPMC queue with head-of-line requeue. Unlike an mpsc
/// channel, a dispatched request can be *put back at the front* when its
/// worker dies, which is the heart of the socket transport's
/// zero-lost-requests guarantee.
#[derive(Default)]
pub struct JobQueue {
    inner: Mutex<JobQueueInner>,
    cv: Condvar,
}

#[derive(Default)]
struct JobQueueInner {
    jobs: VecDeque<PartitionRequest>,
    closed: bool,
}

impl JobQueue {
    pub fn new() -> JobQueue {
        JobQueue::default()
    }

    /// Append a request; false if the queue is closed (request dropped).
    pub fn push(&self, req: PartitionRequest) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return false;
        }
        g.jobs.push_back(req);
        drop(g);
        self.cv.notify_one();
        true
    }

    /// Put a request back at the *front* (dead-worker requeue: the
    /// oldest dispatched work retakes priority over later submissions).
    pub fn push_front(&self, req: PartitionRequest) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return false;
        }
        g.jobs.push_front(req);
        drop(g);
        self.cv.notify_one();
        true
    }

    /// Block until a job is available or the queue closes.
    pub fn pop(&self) -> Option<PartitionRequest> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(job) = g.jobs.pop_front() {
                return Some(job);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Block up to `timeout` for a job.
    pub fn pop_timeout(&self, timeout: Duration) -> Popped {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(job) = g.jobs.pop_front() {
                return Popped::Job(job);
            }
            if g.closed {
                return Popped::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Popped::Empty;
            }
            g = self.cv.wait_timeout(g, deadline - now).unwrap().0;
        }
    }

    /// Close the queue: pending jobs still drain, new pushes fail.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// ModelCache — compiled models shared across requests and workers
// ---------------------------------------------------------------------------

/// Per-model cell: the map lock is only held to look up or insert the
/// cell; the (possibly expensive) compile runs inside the cell's
/// `OnceLock`, so workers serving other, already-cached models never
/// wait behind it. Errors are cached as strings (a zoo model that fails
/// to compile will fail identically every time).
type ModelCell = Arc<std::sync::OnceLock<Result<Arc<CompiledModel>, String>>>;

/// Cache of compiled zoo models, shared by all workers of one process:
/// the NDA and action spaces for a given model are built once per
/// service (or worker-process) lifetime, not once per request.
#[derive(Default)]
pub struct ModelCache {
    cells: Mutex<HashMap<(ModelKind, bool), ModelCell>>,
}

impl ModelCache {
    /// Resolve a request's model source to a compiled model. Zoo models
    /// are compiled once and shared across requests and workers; inline
    /// models are compiled per request (the cache has no identity to key
    /// them on).
    pub fn resolve(&self, source: &ModelSource) -> crate::Result<Arc<CompiledModel>> {
        match source {
            ModelSource::Zoo { kind, paper_scale } => {
                let cell: ModelCell = {
                    let mut cache = self.cells.lock().unwrap();
                    Arc::clone(cache.entry((*kind, *paper_scale)).or_default())
                };
                // Two workers racing on the same *uncompiled* model: one
                // compiles, the other blocks on the cell — never a
                // duplicate NDA run, and never the map lock held across
                // a compile.
                let result = cell.get_or_init(|| {
                    CompiledModel::from_kind(*kind, *paper_scale)
                        .map(Arc::new)
                        .map_err(|e| format!("{e:#}"))
                });
                result.clone().map_err(|e| anyhow!(e))
            }
            ModelSource::Inline(f) => Ok(Arc::new(CompiledModel::compile(f.clone())?)),
        }
    }
}

// ---------------------------------------------------------------------------
// SolutionCache — already-verified artifacts for repeated requests
// ---------------------------------------------------------------------------

/// What makes two requests interchangeable for caching purposes: same
/// serialized model (by fingerprint), mesh layout, topology (by
/// fingerprint — custom machines cache separately from presets), method,
/// budget, and seed. `verify` is deliberately *not* part of the key —
/// a verified artifact can serve both verifying and non-verifying
/// requests; the reverse is gated per entry.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct CacheKey {
    model_fp: u64,
    mesh: Vec<(String, usize)>,
    topology_fp: u64,
    method: &'static str,
    budget: usize,
    seed: u64,
}

impl CacheKey {
    fn of(req: &PartitionRequest) -> CacheKey {
        CacheKey {
            model_fp: req.model.fingerprint(),
            mesh: req.mesh.axes.iter().map(|a| (a.name.clone(), a.size)).collect(),
            topology_fp: req.topology.fingerprint(),
            method: req.method.name(),
            budget: req.budget,
            seed: req.seed,
        }
    }
}

struct CacheEntry {
    solution: Solution,
    /// True when serving this artifact honors a `verify: true` request:
    /// it carries a passing validation record, or the producing request
    /// was exempt from verification (paper-scale IR / verify disabled
    /// service-wide) so a fresh search would not be verified either.
    satisfies_verify: bool,
    /// Monotonic tick of the last hit or insert (LRU eviction order).
    tick: u64,
}

/// LRU-bounded cache of already-completed [`Solution`] artifacts, keyed
/// by [`CacheKey`]. Because single-threaded searches are deterministic
/// for a fixed seed, a cached artifact is byte-identical to what a fresh
/// search would return — the cache changes latency, never results.
///
/// Only *accepted* solutions enter (rejected or failed responses never
/// do), so a hit short-circuits the queue, the search, and the verify
/// replay in one step.
pub struct SolutionCache {
    capacity: usize,
    inner: Mutex<SolutionCacheInner>,
}

#[derive(Default)]
struct SolutionCacheInner {
    entries: HashMap<CacheKey, CacheEntry>,
    tick: u64,
}

impl SolutionCache {
    pub fn new(capacity: usize) -> SolutionCache {
        SolutionCache { capacity, inner: Mutex::new(SolutionCacheInner::default()) }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up a request. `None` when disabled, missing, or when the
    /// entry cannot satisfy the request's verification demand.
    fn lookup(&self, req: &PartitionRequest) -> Option<Solution> {
        if self.capacity == 0 {
            return None;
        }
        let key = CacheKey::of(req);
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        let entry = g.entries.get_mut(&key)?;
        if req.verify && !entry.satisfies_verify {
            return None;
        }
        entry.tick = tick;
        Some(entry.solution.clone())
    }

    /// Insert a completed solution, evicting the least-recently-used
    /// entry at capacity. Returns the resulting cache size.
    fn insert(&self, req: &PartitionRequest, sol: &Solution) -> usize {
        if self.capacity == 0 {
            return 0;
        }
        let key = CacheKey::of(req);
        let satisfies_verify = sol.validation.as_ref().is_some_and(|v| v.pass) || !req.verify;
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if !g.entries.contains_key(&key) && g.entries.len() >= self.capacity {
            if let Some(oldest) =
                g.entries.iter().min_by_key(|(_, e)| e.tick).map(|(k, _)| k.clone())
            {
                g.entries.remove(&oldest);
            }
        }
        g.entries.insert(key, CacheEntry { solution: sol.clone(), satisfies_verify, tick });
        g.entries.len()
    }
}

// ---------------------------------------------------------------------------
// process_request — THE worker code path (threads and processes alike)
// ---------------------------------------------------------------------------

/// Solve and trust-but-verify one request. This is the entire worker
/// code path — the in-process worker threads and the `toast worker
/// --connect` process loop both call it verbatim, which is what keeps
/// the two transports from drifting. It never touches metrics:
/// accounting happens where the response is *received*
/// ([`Metrics::record_response`]), identically for both transports.
pub fn process_request(
    req: &PartitionRequest,
    models: &ModelCache,
    cfg: &ServiceConfig,
) -> PartitionResponse {
    process_request_metered(req, models, cfg, None)
}

/// [`process_request`] with latency accounting: when `metrics` is
/// present, the search and verify phases feed the live latency
/// histograms ([`Metrics::record_search_latency`] /
/// [`Metrics::record_verify_latency`]). Worker processes on the far end
/// of a socket pass `None` — their latencies are observed server-side,
/// where the response is received.
pub fn process_request_metered(
    req: &PartitionRequest,
    models: &ModelCache,
    cfg: &ServiceConfig,
    metrics: Option<&Metrics>,
) -> PartitionResponse {
    let _sp = obs::span("service", "request.process");
    let mut rejected = false;
    let result = (|| -> crate::Result<Solution> {
        let compiled = models.resolve(&req.model)?;
        let mut session = compiled
            .partition(&req.mesh)
            .topology(req.topology.clone())
            .budget(req.budget)
            .seed(req.seed);
        // Deterministic mode: pin the search's internal thread count so a
        // fixed (seed, budget) reproduces bit-identical solutions.
        session = if cfg.search_threads > 0 && req.method == Method::Toast {
            session.strategy(MctsStrategy {
                template: SearchConfig { threads: cfg.search_threads, ..Default::default() },
            })
        } else {
            session.method(req.method)
        };
        let t_search = Instant::now();
        let mut sol = {
            let _sp = obs::span("service", "request.search");
            session.run()?
        };
        if let Some(m) = metrics {
            m.record_search_latency(t_search.elapsed());
        }
        // Trust-but-verify: replay the returned spec through the
        // differential harness before accepting it. The strategy's own
        // claims (cost, spec) are not trusted until the executed sharded
        // module matches the interpreter oracle.
        if cfg.verify && req.verify && compiled.interpreter_sized() {
            let t_verify = Instant::now();
            let replay = {
                let _sp = obs::span("service", "request.verify");
                validate_solution_spec(compiled.func(), &sol.spec, &req.mesh, cfg.verify_seed)
            };
            if let Some(m) = metrics {
                m.record_verify_latency(t_verify.elapsed());
            }
            match replay {
                Ok(record) if record.pass => {
                    sol.validation = Some(record);
                }
                Ok(record) => {
                    rejected = true;
                    anyhow::bail!(
                        "spec rejected by the verification gate: max relative divergence \
                         {:.3e} exceeds tol {:.1e} (strategy {})",
                        record.max_rel_err,
                        record.tol,
                        sol.strategy
                    );
                }
                // A replay that cannot even run (spec fails the
                // structural check, partitioning or execution errors) is
                // just as untrustworthy as a diverging one.
                Err(e) => {
                    rejected = true;
                    return Err(e.context(format!(
                        "spec rejected by the verification gate: replay failed (strategy {})",
                        sol.strategy
                    )));
                }
            }
        }
        Ok(sol)
    })();
    PartitionResponse { id: req.id, request: req.clone(), result, rejected }
}

// ---------------------------------------------------------------------------
// Service
// ---------------------------------------------------------------------------

/// Live bookkeeping for one worker — an in-process thread or a remote
/// `toast worker` connection — feeding the `workers_detail` section of
/// [`StatusReport`]. Counters are relaxed atomics: the detail list is an
/// operator snapshot, not an accounting source of truth (that is
/// [`Metrics`]).
pub(crate) struct WorkerEntry {
    pub(crate) name: String,
    /// Pipelining depth (1 for thread workers; the feeder capacity for
    /// socket workers).
    pub(crate) capacity: u64,
    pub(crate) in_flight: AtomicU64,
    pub(crate) completed: AtomicU64,
    /// Last observed activity (spawn/heartbeat/result).
    pub(crate) last_seen: Mutex<Instant>,
}

impl WorkerEntry {
    pub(crate) fn new(name: String, capacity: u64) -> WorkerEntry {
        WorkerEntry {
            name,
            capacity,
            in_flight: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            last_seen: Mutex::new(Instant::now()),
        }
    }

    pub(crate) fn touch(&self) {
        *self.last_seen.lock().unwrap() = Instant::now();
    }
}

/// State shared between the service handle, its worker threads, and (in
/// socket mode) the TCP transport layer.
pub(crate) struct ServiceShared {
    pub(crate) queue: JobQueue,
    pub(crate) metrics: Arc<Metrics>,
    pub(crate) models: ModelCache,
    pub(crate) cache: SolutionCache,
    pub(crate) cfg: ServiceConfig,
    next_id: AtomicU64,
    pub(crate) next_worker_id: AtomicU64,
    /// Transport bookkeeping: how many times each request id has been
    /// requeued after a worker death. Bounds the damage of a poison
    /// request (one whose search kills its worker) — see
    /// [`super::transport`]'s `MAX_REQUEUES` guard. Entries are removed
    /// when a request completes.
    pub(crate) requeue_counts: Mutex<HashMap<u64, u32>>,
    /// Admission timestamps of queued requests; taken at dispatch to
    /// feed the queue-wait latency histogram. Entries for requeued
    /// requests were consumed at first dispatch, so a requeue's second
    /// wait is deliberately not double-counted.
    enqueue_times: Mutex<HashMap<u64, Instant>>,
    /// Live workers by id — thread workers register at spawn, socket
    /// workers at their `Register` frame; both deregister on exit/death.
    pub(crate) worker_registry: Mutex<HashMap<u64, Arc<WorkerEntry>>>,
    /// Master response sender; worker/transport threads clone it. Taken
    /// (set to `None`) at shutdown so the response channel disconnects
    /// once the last worker drops its clone.
    resp_tx: Mutex<Option<Sender<PartitionResponse>>>,
    /// In-process worker threads still alive (panics decrement too).
    local_alive: AtomicU64,
    /// A socket transport is attached, so remote workers may serve the
    /// queue even when no local thread does.
    transport_attached: AtomicBool,
}

impl ServiceShared {
    pub(crate) fn allocate_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub(crate) fn response_sender(&self) -> Option<Sender<PartitionResponse>> {
        self.resp_tx.lock().unwrap().clone()
    }

    pub(crate) fn attach_transport(&self) {
        self.transport_attached.store(true, Ordering::Relaxed);
    }

    /// Drop the master response sender so the response channel
    /// disconnects once the last worker/transport clone is gone.
    pub(crate) fn take_response_sender(&self) {
        let _ = self.resp_tx.lock().unwrap().take();
    }

    /// Queue `req` (its id must already be assigned). Fails — instead of
    /// silently parking the request forever — when the service has shut
    /// down or has no way left to process it.
    pub(crate) fn enqueue(&self, req: PartitionRequest) -> crate::Result<()> {
        let id = req.id;
        if self.local_alive.load(Ordering::Relaxed) == 0
            && !self.transport_attached.load(Ordering::Relaxed)
        {
            return Err(anyhow!(
                "partition service has no workers (threads exited, no transport attached); \
                 request {id} dropped"
            ));
        }
        // Enqueue gauge goes up *before* the push: once the request is
        // in the queue a worker may dispatch it immediately, and its
        // decrement must always pair with this increment. The queue-wait
        // clock starts here for the same reason.
        self.metrics.record_enqueue();
        self.enqueue_times.lock().unwrap().insert(id, Instant::now());
        obs::event("service", "request.enqueue");
        if !self.queue.push(req) {
            self.metrics.record_unqueue();
            self.enqueue_times.lock().unwrap().remove(&id);
            return Err(anyhow!("partition service is shut down; request {id} dropped"));
        }
        self.metrics.record_request();
        Ok(())
    }

    /// The admission path shared by both transports: cache lookup first,
    /// then the queue-depth bound, then the queue. Returns
    /// `Ok(Some(response))` on a cache hit — the response is fully
    /// formed and *nothing was queued or dispatched* — `Ok(None)` when
    /// the request entered the queue, and `Err` when it was refused
    /// (shutdown, no workers, or [`Overloaded`], which callers can
    /// downcast to distinguish backpressure from hard failures).
    pub(crate) fn admit(
        &self,
        req: PartitionRequest,
    ) -> crate::Result<Option<PartitionResponse>> {
        let _sp = obs::span("service", "request.admit");
        let t0 = Instant::now();
        if !req.no_cache {
            if let Some(sol) = self.cache.lookup(&req) {
                let result = Ok(sol);
                let resp = PartitionResponse { id: req.id, request: req, result, rejected: false };
                self.metrics.record_cache_hit(&resp);
                self.metrics.record_cache_hit_latency(t0.elapsed());
                return Ok(Some(resp));
            }
            self.metrics.record_cache_miss();
        }
        if self.cfg.max_queue > 0 {
            let queued = self.metrics.queue_depth();
            if queued >= self.cfg.max_queue as u64 {
                self.metrics.record_overloaded();
                return Err(anyhow::Error::new(Overloaded {
                    queued,
                    limit: self.cfg.max_queue as u64,
                }));
            }
        }
        self.enqueue(req)?;
        Ok(None)
    }

    /// The single terminal path for a dispatched request, shared by the
    /// in-process worker loop and every socket-side completion (matched
    /// result, poison-request fail-back): populate the solution cache,
    /// clear the request's requeue ledger entry, then account the
    /// response. Centralizing the ledger clear is what keeps
    /// `requeue_counts` from leaking entries on any terminal path.
    pub(crate) fn complete_response(&self, resp: &PartitionResponse) {
        obs::event("service", "request.respond");
        if let Ok(sol) = &resp.result {
            let size = self.cache.insert(&resp.request, sol);
            self.metrics.set_cache_size(size as u64);
        }
        self.requeue_counts.lock().unwrap().remove(&resp.id);
        // Defensive: dispatch already consumed the queue-wait entry;
        // this only matters for a request failed back without one.
        self.enqueue_times.lock().unwrap().remove(&resp.id);
        self.metrics.record_response(resp);
    }

    /// Requeue-ledger entries still outstanding (tests assert 0 after
    /// terminal scenarios — a nonzero steady-state value is a leak).
    pub(crate) fn pending_requeue_entries(&self) -> usize {
        self.requeue_counts.lock().unwrap().len()
    }

    /// Account a dispatch: the in-flight gauge, plus the request's queue
    /// wait (admission → dispatch) into the latency histogram. Requeued
    /// requests consumed their ledger entry at first dispatch and record
    /// nothing further.
    pub(crate) fn note_dispatch(&self, id: u64) {
        obs::event("service", "request.dispatch");
        self.metrics.record_dispatch();
        let waited = self.enqueue_times.lock().unwrap().remove(&id);
        if let Some(t0) = waited {
            self.metrics.record_queue_wait(t0.elapsed());
        }
    }

    /// Register a worker under `id`. The returned entry is shared: the
    /// caller updates its counters, the registry renders them.
    pub(crate) fn register_worker(
        &self,
        id: u64,
        name: String,
        capacity: u64,
    ) -> Arc<WorkerEntry> {
        let entry = Arc::new(WorkerEntry::new(name, capacity));
        self.worker_registry.lock().unwrap().insert(id, Arc::clone(&entry));
        entry
    }

    pub(crate) fn deregister_worker(&self, id: u64) {
        self.worker_registry.lock().unwrap().remove(&id);
    }

    /// Per-worker operator snapshot, ordered by worker id.
    pub(crate) fn workers_detail(&self) -> Vec<WorkerDetail> {
        let g = self.worker_registry.lock().unwrap();
        let mut v: Vec<WorkerDetail> = g
            .iter()
            .map(|(&id, e)| WorkerDetail {
                id,
                name: e.name.clone(),
                capacity: e.capacity,
                in_flight: e.in_flight.load(Ordering::Relaxed),
                completed: e.completed.load(Ordering::Relaxed),
                last_heartbeat_ms: e.last_seen.lock().unwrap().elapsed().as_millis() as u64,
            })
            .collect();
        v.sort_by_key(|w| w.id);
        v
    }

    /// The full status document: counter totals and latency digests from
    /// [`Metrics::report`], plus the live per-worker detail only this
    /// layer knows.
    pub(crate) fn status_report(&self) -> StatusReport {
        let mut report = self.metrics.report();
        report.workers_detail = self.workers_detail();
        report
    }

    /// Prometheus text exposition of every counter, gauge and histogram.
    pub(crate) fn prometheus_text(&self) -> String {
        self.metrics.prometheus_text()
    }
}

/// Decrements a liveness gauge when dropped — worker threads hold one so
/// even a panicking worker is accounted as gone (and deregistered from
/// the worker detail list).
struct AliveGuard {
    shared: Arc<ServiceShared>,
    worker_id: u64,
}

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.shared.deregister_worker(self.worker_id);
        self.shared.local_alive.fetch_sub(1, Ordering::Relaxed);
        self.shared.metrics.record_worker_lost();
    }
}

/// The running service: a [`JobQueue`], a [`ModelCache`], and zero or
/// more in-process worker threads. The socket transport
/// ([`super::transport::TcpServer`]) wraps a `Service` and adds remote
/// workers pulling from the *same* queue.
pub struct Service {
    pub(crate) shared: Arc<ServiceShared>,
    pub responses: Receiver<PartitionResponse>,
    pub metrics: Arc<Metrics>,
    pub(crate) workers: Vec<JoinHandle<()>>,
}

impl Service {
    /// Spawn a service with `n_workers` worker threads (at least one)
    /// and default verification settings.
    pub fn start(n_workers: usize) -> Service {
        Self::start_with(ServiceConfig { workers: n_workers.max(1), ..Default::default() })
    }

    /// Spawn a service with explicit configuration. `cfg.workers == 0`
    /// starts a transport-only service (no local threads); submissions
    /// are rejected until a transport is attached.
    pub fn start_with(cfg: ServiceConfig) -> Service {
        let (resp_tx, responses) = channel::<PartitionResponse>();
        let metrics = Arc::new(Metrics::default());
        let shared = Arc::new(ServiceShared {
            queue: JobQueue::new(),
            metrics: Arc::clone(&metrics),
            models: ModelCache::default(),
            cache: SolutionCache::new(cfg.cache_capacity),
            cfg: cfg.clone(),
            next_id: AtomicU64::new(1),
            next_worker_id: AtomicU64::new(1),
            requeue_counts: Mutex::new(HashMap::new()),
            enqueue_times: Mutex::new(HashMap::new()),
            worker_registry: Mutex::new(HashMap::new()),
            resp_tx: Mutex::new(Some(resp_tx)),
            local_alive: AtomicU64::new(0),
            transport_attached: AtomicBool::new(false),
        });
        let mut workers = Vec::new();
        for _ in 0..cfg.workers {
            shared.local_alive.fetch_add(1, Ordering::Relaxed);
            shared.metrics.record_worker_connected();
            let worker_id = shared.next_worker_id.fetch_add(1, Ordering::Relaxed);
            let entry = shared.register_worker(worker_id, format!("local-{worker_id}"), 1);
            let tx = shared.response_sender().expect("sender alive at startup");
            let shared = Arc::clone(&shared);
            workers.push(std::thread::spawn(move || {
                let _guard = AliveGuard { shared: Arc::clone(&shared), worker_id };
                while let Some(req) = shared.queue.pop() {
                    shared.note_dispatch(req.id);
                    entry.in_flight.store(1, Ordering::Relaxed);
                    let resp = process_request_metered(
                        &req,
                        &shared.models,
                        &shared.cfg,
                        Some(&shared.metrics),
                    );
                    entry.in_flight.store(0, Ordering::Relaxed);
                    entry.completed.fetch_add(1, Ordering::Relaxed);
                    entry.touch();
                    shared.complete_response(&resp);
                    if tx.send(resp).is_err() {
                        break;
                    }
                }
            }));
        }
        Service { shared, responses, metrics, workers }
    }

    /// Submit a request; returns its id, or an error if the service has
    /// shut down (queue closed / workers gone), or — when an admission
    /// bound is configured — an [`Overloaded`] refusal (downcastable) if
    /// the queue sits at its bound. Cache hits are answered immediately:
    /// the cached response arrives on [`Service::responses`] without any
    /// worker dispatch.
    pub fn submit(&self, mut req: PartitionRequest) -> crate::Result<u64> {
        let id = self.shared.allocate_id();
        req.id = id;
        if let Some(resp) = self.shared.admit(req)? {
            let tx = self
                .shared
                .response_sender()
                .ok_or_else(|| anyhow!("partition service is shut down; request {id} dropped"))?;
            tx.send(resp).map_err(|_| anyhow!("response channel closed; request {id} dropped"))?;
        }
        Ok(id)
    }

    /// Solutions currently held by the server-side cache.
    pub fn cache_len(&self) -> usize {
        self.shared.cache.len()
    }

    /// The same status document a socket `status` request answers with:
    /// counter totals, per-phase latency digests, per-worker detail.
    pub fn status_report(&self) -> StatusReport {
        self.shared.status_report()
    }

    /// Prometheus text exposition of the service's live metrics.
    pub fn prometheus_text(&self) -> String {
        self.shared.prometheus_text()
    }

    /// Requeue-ledger entries still outstanding (0 once every dispatched
    /// request reached a terminal path).
    pub fn pending_requeue_entries(&self) -> usize {
        self.shared.pending_requeue_entries()
    }

    /// Close the queue without consuming the handle: queued jobs still
    /// drain, further submits fail.
    pub fn close(&self) {
        self.shared.queue.close();
    }

    /// Shut down: close the queue, release the response channel, and
    /// join the local workers.
    pub fn shutdown(self) {
        self.shared.queue.close();
        // Drop the master sender so `responses` disconnects once the
        // last worker finishes.
        self.shared.resp_tx.lock().unwrap().take();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Convenience default request (scaled zoo model, 2x2 mesh, A100).
pub fn default_request(model: ModelKind, method: Method) -> PartitionRequest {
    PartitionRequest {
        id: 0,
        model: ModelSource::zoo(model),
        mesh: Mesh::grid(&[("data", 2), ("model", 2)]),
        topology: Topology::from_kind(HardwareKind::A100),
        method,
        budget: 150,
        seed: 0,
        verify: true,
        no_cache: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn service_processes_requests() {
        let svc = Service::start(2);
        let mut ids = Vec::new();
        for method in [Method::Toast, Method::Manual] {
            ids.push(svc.submit(default_request(ModelKind::Mlp, method)).unwrap());
        }
        let mut got = Vec::new();
        for _ in 0..ids.len() {
            let resp = svc.responses.recv().expect("response");
            let sol = resp.result.as_ref().expect("job succeeds");
            // trust-but-verify ran and passed
            let v = sol.validation.as_ref().expect("verification record");
            assert!(v.pass);
            got.push(resp.id);
        }
        got.sort_unstable();
        assert_eq!(got, ids);
        let snap = svc.metrics.snapshot();
        assert!(snap.contains("completed=2"), "{snap}");
        assert!(snap.contains("verified=2"), "{snap}");
        assert!(snap.contains("queued=0"), "{snap}");
        assert!(snap.contains("in_flight=0"), "{snap}");
        svc.shutdown();
    }

    #[test]
    fn submit_after_close_errors_instead_of_panicking() {
        // A closed service behaves exactly like one whose workers all
        // died: submit must surface an Err, not panic or hang.
        let svc = Service::start(1);
        svc.close();
        let err = svc.submit(default_request(ModelKind::Mlp, Method::Manual));
        assert!(err.is_err(), "submit after close must be an Err, not a panic");
        assert_eq!(svc.metrics.queue_depth(), 0, "failed submits are not queued");
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn transport_only_service_rejects_submits_until_attached() {
        let svc = Service::start_with(ServiceConfig { workers: 0, ..Default::default() });
        let err = svc.submit(default_request(ModelKind::Mlp, Method::Manual));
        assert!(err.is_err(), "no workers and no transport: the request could never run");
        svc.shared.attach_transport();
        let id = svc.submit(default_request(ModelKind::Mlp, Method::Manual)).unwrap();
        assert!(id > 0);
        assert_eq!(svc.metrics.queue_depth(), 1, "request waits for a remote worker");
        svc.shutdown();
    }

    #[test]
    fn inline_models_are_served() {
        let mut b = crate::ir::FuncBuilder::new("inline_mlp");
        let x = b.param("x", crate::ir::TensorType::f32(vec![16, 8]));
        let w = b.param("w", crate::ir::TensorType::f32(vec![8, 4]));
        let y = b.matmul(x, w);
        let func = b.build(vec![y]);
        let svc = Service::start(1);
        let mut req = default_request(ModelKind::Mlp, Method::Toast);
        req.model = ModelSource::Inline(func);
        req.budget = 40;
        svc.submit(req).unwrap();
        let resp = svc.responses.recv().unwrap();
        let sol = resp.result.expect("inline job succeeds");
        assert!(sol.validation.expect("verified").pass);
        assert!(matches!(sol.model, ModelSource::Inline(_)));
        svc.shutdown();
    }

    #[test]
    fn request_and_response_roundtrip_json() {
        let req = default_request(ModelKind::Attention, Method::Alpa);
        let jr = Json::parse(&req.to_json().render()).unwrap();
        let back = PartitionRequest::from_json(&jr).unwrap();
        assert_eq!(back.model, req.model);
        assert_eq!(back.mesh, req.mesh);
        assert_eq!(back.method, req.method);
        assert_eq!(back.topology, req.topology);
        assert_eq!(back.budget, req.budget);
        assert_eq!(back.verify, req.verify);

        // An error response survives the wire too, rejection flag and all.
        let resp = PartitionResponse {
            id: 9,
            request: req,
            result: Err(anyhow!("strategy exploded")),
            rejected: true,
        };
        let jr = Json::parse(&resp.to_json().render()).unwrap();
        let back = PartitionResponse::from_json(&jr).unwrap();
        assert_eq!(back.id, 9);
        assert!(back.rejected);
        assert!(back.result.is_err());
        assert!(format!("{:#}", back.result.unwrap_err()).contains("strategy exploded"));
    }

    #[test]
    fn job_queue_requeues_at_the_front() {
        let q = JobQueue::new();
        assert!(q.push(default_request(ModelKind::Mlp, Method::Manual)));
        assert!(q.push(default_request(ModelKind::Attention, Method::Manual)));
        let first = q.pop().unwrap();
        assert_eq!(first.model, ModelSource::zoo(ModelKind::Mlp));
        // A dead worker's job goes back to the *head* of the line.
        assert!(q.push_front(first));
        let again = q.pop().unwrap();
        assert_eq!(again.model, ModelSource::zoo(ModelKind::Mlp));
        assert_eq!(q.len(), 1);
        q.close();
        assert!(!q.push(default_request(ModelKind::Mlp, Method::Manual)));
        // Close drains: the remaining job still pops, then Closed.
        assert!(matches!(q.pop_timeout(Duration::from_millis(10)), Popped::Job(_)));
        assert!(matches!(q.pop_timeout(Duration::from_millis(10)), Popped::Closed));
        assert!(q.pop().is_none());
    }

    #[test]
    fn pop_timeout_reports_empty_on_an_open_queue() {
        let q = JobQueue::new();
        let t0 = Instant::now();
        assert!(matches!(q.pop_timeout(Duration::from_millis(30)), Popped::Empty));
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn solution_cache_is_lru_bounded_and_gates_on_verification() {
        // Produce one real artifact cheaply (manual strategy, no verify).
        let models = ModelCache::default();
        let cfg = ServiceConfig { verify: false, ..Default::default() };
        let mut req = default_request(ModelKind::Mlp, Method::Manual);
        req.verify = false;
        let sol = process_request(&req, &models, &cfg).result.expect("manual partition");

        let cache = SolutionCache::new(2);
        let reqs: Vec<PartitionRequest> = (0..3u64)
            .map(|seed| {
                let mut r = req.clone();
                r.seed = seed;
                r
            })
            .collect();
        assert_eq!(cache.insert(&reqs[0], &sol), 1);
        assert_eq!(cache.insert(&reqs[1], &sol), 2);
        // Touch entry 0 so entry 1 becomes the LRU victim.
        assert!(cache.lookup(&reqs[0]).is_some());
        assert_eq!(cache.insert(&reqs[2], &sol), 2);
        assert!(cache.lookup(&reqs[0]).is_some());
        assert!(cache.lookup(&reqs[1]).is_none(), "LRU victim must be evicted");
        assert!(cache.lookup(&reqs[2]).is_some());

        // An artifact produced without verification never serves a
        // verify=true request.
        let mut verifying = reqs[0].clone();
        verifying.verify = true;
        assert!(cache.lookup(&verifying).is_none());

        // Capacity 0 disables the cache entirely.
        let off = SolutionCache::new(0);
        assert_eq!(off.insert(&reqs[0], &sol), 0);
        assert!(off.lookup(&reqs[0]).is_none());
    }

    #[test]
    fn cache_hit_returns_byte_identical_artifact_without_a_dispatch() {
        let svc = Service::start_with(ServiceConfig {
            workers: 1,
            search_threads: 1,
            ..Default::default()
        });
        let req = default_request(ModelKind::Mlp, Method::Toast);
        svc.submit(req.clone()).unwrap();
        let first = svc.responses.recv().unwrap();
        let sol1 = first.result.expect("search succeeds");
        assert_eq!(svc.cache_len(), 1, "accepted solution entered the cache");
        let evals_after_search = svc.metrics.evaluations.load(Ordering::Relaxed);
        assert!(evals_after_search > 0);

        // Identical request: answered from the cache, byte for byte,
        // with zero additional search work.
        svc.submit(req.clone()).unwrap();
        let second = svc.responses.recv().unwrap();
        let sol2 = second.result.expect("cache hit succeeds");
        assert_eq!(
            sol1.to_json().render(),
            sol2.to_json().render(),
            "cached artifact must be byte-identical"
        );
        assert_eq!(svc.metrics.cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(svc.metrics.cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(
            svc.metrics.evaluations.load(Ordering::Relaxed),
            evals_after_search,
            "a cache hit runs no search"
        );
        assert_eq!(svc.metrics.queue_depth(), 0);
        assert_eq!(svc.metrics.in_flight.load(Ordering::Relaxed), 0);

        // --no-cache forces a fresh dispatch even with a warm cache.
        let mut fresh = req.clone();
        fresh.no_cache = true;
        svc.submit(fresh).unwrap();
        let third = svc.responses.recv().unwrap();
        let sol3 = third.result.expect("fresh search succeeds");
        assert_eq!(svc.metrics.cache_hits.load(Ordering::Relaxed), 1, "bypassed");
        assert!(svc.metrics.evaluations.load(Ordering::Relaxed) > evals_after_search);
        // Determinism check rides along: the fresh single-threaded
        // search reproduces the cached artifact exactly (modulo wall
        // time, which the canonical form zeroes).
        let mut c1 = sol1.clone();
        let mut c3 = sol3.clone();
        c1.search_time_s = 0.0;
        c3.search_time_s = 0.0;
        assert_eq!(c1.to_json().render(), c3.to_json().render());
        svc.shutdown();
    }

    #[test]
    fn status_report_carries_worker_detail_and_latency_digests() {
        let svc = Service::start_with(ServiceConfig {
            workers: 2,
            search_threads: 1,
            ..Default::default()
        });
        let req = default_request(ModelKind::Mlp, Method::Toast);
        svc.submit(req.clone()).unwrap();
        let _ = svc.responses.recv().unwrap();
        // The identical request hits the cache: the cache_hit phase gets
        // its first sample while search_cold keeps exactly one.
        svc.submit(req).unwrap();
        let _ = svc.responses.recv().unwrap();

        let report = svc.shared.status_report();
        assert_eq!(report.workers_detail.len(), 2, "both thread workers registered");
        assert!(report.workers_detail.iter().all(|w| w.capacity == 1 && w.in_flight == 0));
        assert_eq!(report.workers_detail.iter().map(|w| w.completed).sum::<u64>(), 1);
        let phases: Vec<&str> = report.latency.iter().map(|l| l.phase.as_str()).collect();
        for phase in ["queue_wait", "search_cold", "cache_hit", "verify"] {
            assert!(phases.contains(&phase), "missing {phase} in {phases:?}");
        }
        // The report round-trips (socket mode ships it as a frame).
        let back =
            StatusReport::from_json(&Json::parse(&report.to_json().render()).unwrap()).unwrap();
        assert_eq!(back, report);

        let prom = svc.shared.prometheus_text();
        assert!(prom.contains("toast_requests_total 2"), "{prom}");
        assert!(prom.contains("toast_request_latency_us_bucket{phase=\"search_cold\""), "{prom}");
        svc.shutdown();
    }

    #[test]
    fn admission_bound_refuses_with_overloaded_and_drains() {
        // Transport-attached service with no local workers: requests
        // park in the queue, so the bound is deterministic.
        let svc = Service::start_with(ServiceConfig {
            workers: 0,
            max_queue: 2,
            ..Default::default()
        });
        svc.shared.attach_transport();
        let mk_req = |seed: u64| {
            let mut r = default_request(ModelKind::Mlp, Method::Manual);
            r.seed = seed;
            r.no_cache = true;
            r
        };
        svc.submit(mk_req(1)).unwrap();
        svc.submit(mk_req(2)).unwrap();
        let err = svc.submit(mk_req(3)).unwrap_err();
        let over = err.downcast_ref::<Overloaded>().expect("structured overload refusal");
        assert_eq!(over.queued, 2);
        assert_eq!(over.limit, 2);
        assert_eq!(svc.metrics.overloaded.load(Ordering::Relaxed), 1);
        assert_eq!(svc.metrics.queue_depth(), 2, "refused request never queued");

        // Drain one (as a worker pickup would) and admission reopens.
        let _job = svc.shared.queue.pop().expect("queued job");
        svc.metrics.record_dispatch();
        svc.submit(mk_req(4)).expect("below the bound again");
        assert_eq!(svc.metrics.queue_depth(), 2);
        svc.shutdown();
    }
}
