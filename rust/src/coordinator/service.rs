//! The partition service: a request queue with a worker-thread pool.
//!
//! Requests carry everything a partitioning job needs; workers build the
//! model IR, run the requested method, and push responses to the shared
//! response channel. The service is synchronous-friendly (submit then
//! `recv` responses) and is what `toast serve` wraps.

use super::metrics::Metrics;
use crate::baselines::{run_method, Method, MethodResult};
use crate::cost::CostModel;
use crate::mesh::{HardwareKind, HardwareProfile, Mesh};
use crate::models::ModelKind;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A partitioning request.
#[derive(Clone, Debug)]
pub struct PartitionRequest {
    pub id: u64,
    pub model: ModelKind,
    /// Use paper-size IR (true) or the scaled variant (false).
    pub paper_scale: bool,
    /// Mesh axes: (name, size) pairs.
    pub mesh: Vec<(String, usize)>,
    pub hardware: HardwareKind,
    pub method: Method,
    /// Search budget (state evaluations).
    pub budget: usize,
    pub seed: u64,
}

/// A completed partitioning job.
pub struct PartitionResponse {
    pub id: u64,
    pub request: PartitionRequest,
    pub result: anyhow::Result<MethodResult>,
}

/// The running service.
pub struct Service {
    tx: Sender<PartitionRequest>,
    pub responses: Receiver<PartitionResponse>,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Service {
    /// Spawn a service with `n_workers` worker threads.
    pub fn start(n_workers: usize) -> Service {
        let (tx, rx) = channel::<PartitionRequest>();
        let rx = Arc::new(Mutex::new(rx));
        let (resp_tx, responses) = channel::<PartitionResponse>();
        let metrics = Arc::new(Metrics::default());
        let mut workers = Vec::new();
        for _ in 0..n_workers.max(1) {
            let rx = Arc::clone(&rx);
            let resp_tx = resp_tx.clone();
            let metrics = Arc::clone(&metrics);
            workers.push(std::thread::spawn(move || loop {
                let req = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok(req) = req else { break };
                let result = handle(&req);
                match &result {
                    Ok(r) => metrics.record_completion(r.search_time, 0, r.oom),
                    Err(_) => metrics.record_failure(),
                }
                if resp_tx.send(PartitionResponse { id: req.id, request: req, result }).is_err()
                {
                    break;
                }
            }));
        }
        Service { tx, responses, metrics, workers, next_id: AtomicU64::new(1) }
    }

    /// Submit a request; returns its id.
    pub fn submit(&self, mut req: PartitionRequest) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        req.id = id;
        self.metrics.record_request();
        self.tx.send(req).expect("service workers alive");
        id
    }

    /// Shut down: close the queue and join workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn handle(req: &PartitionRequest) -> anyhow::Result<MethodResult> {
    let func =
        if req.paper_scale { req.model.build_paper() } else { req.model.build_scaled() };
    let axes: Vec<(&str, usize)> =
        req.mesh.iter().map(|(n, s)| (n.as_str(), *s)).collect();
    let mesh = Mesh::grid(&axes);
    let model = CostModel::new(HardwareProfile::new(req.hardware));
    Ok(run_method(req.method, req.model, &func, &mesh, &model, req.budget, req.seed))
}

/// Convenience default request.
pub fn default_request(model: ModelKind, method: Method) -> PartitionRequest {
    PartitionRequest {
        id: 0,
        model,
        paper_scale: false,
        mesh: vec![("data".into(), 2), ("model".into(), 2)],
        hardware: HardwareKind::A100,
        method,
        budget: 150,
        seed: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_processes_requests() {
        let svc = Service::start(2);
        let mut ids = Vec::new();
        for method in [Method::Toast, Method::Manual] {
            ids.push(svc.submit(default_request(ModelKind::Mlp, method)));
        }
        let mut got = Vec::new();
        for _ in 0..ids.len() {
            let resp = svc.responses.recv().expect("response");
            assert!(resp.result.is_ok(), "{:?}", resp.result.err());
            got.push(resp.id);
        }
        got.sort_unstable();
        assert_eq!(got, ids);
        assert!(svc.metrics.snapshot().contains("completed=2"));
        svc.shutdown();
    }

    #[test]
    fn failed_jobs_counted() {
        // A mesh with a bad axis size still works (size 1) — craft a
        // working request and check metrics coherence instead.
        let svc = Service::start(1);
        svc.submit(default_request(ModelKind::Mlp, Method::AutoMap));
        let resp = svc.responses.recv().unwrap();
        assert!(resp.result.is_ok());
        svc.shutdown();
    }
}
