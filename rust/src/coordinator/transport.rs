//! Socket transport for the partition service: length-prefixed JSON
//! frames over TCP, the `toast serve --listen` server, the
//! `toast worker --connect` process loop, and the submit client.
//!
//! ## Wire protocol
//!
//! A *frame* is a 4-byte big-endian payload length followed by that many
//! bytes of UTF-8 JSON — one [`Message`] per frame. Frames larger than
//! [`MAX_FRAME_LEN`] (64 MiB, comfortably above any inline-IR request)
//! are rejected without reading the payload. Malformed frames and JSON
//! parse failures poison only their own connection: the handler answers
//! with a best-effort [`Message::Error`] and closes that one socket; the
//! listener keeps accepting. Partial reads are handled by the codec
//! (framing never assumes a frame arrives in one `read`).
//!
//! ## Roles
//!
//! * **Workers** connect, send `register`, receive `registered`, then
//!   loop `job` → `result`. A background thread sends `heartbeat` every
//!   [`HEARTBEAT_INTERVAL`] — even mid-search — so the server can tell a
//!   long job from a dead process. The `toast worker` CLI runs
//!   [`run_worker_reconnect`]: a lost connection retries with
//!   exponential backoff ([`ReconnectPolicy`]), so a restarted server
//!   picks its fleet back up without re-spawning worker processes.
//! * **Clients** connect and send `submit` (acked with `submitted`),
//!   `status` (answered with `status_report`) and `metrics` (answered
//!   with `metrics_report` — the Prometheus text exposition); completed
//!   `response` frames arrive as workers finish.
//!
//! ## Liveness and requeue
//!
//! The server tracks `last_seen` per worker. A worker that goes silent
//! for longer than [`TcpServerConfig::dead_after`] — or whose socket
//! errors or closes — is declared dead: *all* of its in-flight requests
//! (a worker holds up to [`TcpServerConfig::capacity`] pipelined jobs)
//! are put back at the *front* of the shared [`JobQueue`] (counted in
//! [`Metrics::requeued`]) and completed by surviving workers, so a
//! `kill -9` mid-search loses zero requests. A request that keeps
//! killing its workers is capped at [`MAX_REQUEUES`] retries and then
//! failed back to its client — one poison request cannot serially take
//! down the fleet.
//!
//! ## Throughput and trust
//!
//! Submissions run the cache-first admission path shared with the
//! thread mode ([`ServiceShared::admit`]): a repeated request is
//! answered from the server's solution cache without touching the
//! queue, and a queue at its admission bound refuses the submit with a
//! structured `overloaded` frame the client can back off on. Dispatch
//! and verification share the in-process mode's code path: remote
//! workers run [`process_request`] (compiled-model cache +
//! trust-but-verify differential replay) and the server accounts every
//! response through the same terminal path the thread mode uses — so
//! the transports cannot drift. Because workers run their *own*
//! differential replay, a Byzantine worker could forge the validation
//! record; the server therefore replays a sampled fraction
//! ([`TcpServerConfig::audit_fraction`]) of worker-claimed records
//! itself and rejects any result whose claim does not reproduce.

use super::metrics::Metrics;
use super::service::{
    process_request, ModelCache, Overloaded, Popped, Service, ServiceConfig, ServiceShared,
    WorkerEntry,
};
use crate::api::wire::{Message, StatusReport};
use crate::api::{
    validate_solution_spec, validate_staged_solution_spec, PartitionRequest, PartitionResponse,
};
use crate::util::json::Json;
use crate::util::Rng;
use anyhow::{anyhow, bail, ensure, Context as _};
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Hard cap on one frame's payload. Large enough for paper-scale inline
/// IR, small enough that a garbage length prefix cannot make the server
/// allocate unbounded memory.
pub const MAX_FRAME_LEN: usize = 64 << 20;

/// How often a worker process beacons liveness.
pub const HEARTBEAT_INTERVAL: Duration = Duration::from_millis(500);

/// Default silence window after which the server declares a worker dead.
pub const DEFAULT_DEAD_AFTER: Duration = Duration::from_secs(5);

/// Bound on a worker's socket writes (heartbeats and results): a dead or
/// wedged server connection fails the write within this window instead
/// of blocking a thread forever, which is what keeps the heartbeat
/// thread joinable and reconnect cycles prompt.
pub const WORKER_WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Poison-request guard: how many times a request may be requeued after
/// killing its worker before the server gives up and fails it. Without a
/// cap, one request whose search crashes the worker process would be
/// handed to every fresh worker in turn — serially killing the whole
/// fleet and starving every request queued behind it.
pub const MAX_REQUEUES: u32 = 2;

// ---------------------------------------------------------------------------
// Framing codec (pure functions — unit-tested without sockets)
// ---------------------------------------------------------------------------

/// Encode one frame: 4-byte big-endian length prefix + payload.
pub fn encode_frame(payload: &[u8]) -> crate::Result<Vec<u8>> {
    ensure!(
        payload.len() <= MAX_FRAME_LEN,
        "frame payload of {} bytes exceeds the {MAX_FRAME_LEN}-byte cap",
        payload.len()
    );
    let mut frame = Vec::with_capacity(4 + payload.len());
    frame.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    frame.extend_from_slice(payload);
    Ok(frame)
}

/// Write one frame. The prefix and payload go out as a single buffer so
/// a frame is never interleaved with another writer's bytes as long as
/// callers serialize on the stream (all writers here hold a mutex).
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> crate::Result<()> {
    let frame = encode_frame(payload)?;
    w.write_all(&frame).context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// What a timeout-aware frame read observed.
pub enum FrameEvent {
    /// A complete frame's payload.
    Frame(Vec<u8>),
    /// The read timed out *before any byte of a frame arrived* — the
    /// peer is merely quiet, not mid-frame. Only possible on streams
    /// with a read timeout set.
    Idle,
    /// Clean EOF at a frame boundary.
    Closed,
}

/// Read one frame, distinguishing "no frame started yet" (`Idle`, on a
/// timed-out stream) from "peer stalled mid-frame" (an error): once the
/// first prefix byte arrives the rest of the frame must follow within
/// the stream's timeout. Handles arbitrarily fragmented delivery — the
/// length prefix and payload may arrive one byte at a time.
pub fn read_frame_event(r: &mut impl Read, cap: usize) -> crate::Result<FrameEvent> {
    let mut prefix = [0u8; 4];
    loop {
        match r.read(&mut prefix[..1]) {
            Ok(0) => return Ok(FrameEvent::Closed),
            Ok(_) => break,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                return Ok(FrameEvent::Idle)
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(anyhow!(e).context("reading frame prefix")),
        }
    }
    r.read_exact(&mut prefix[1..]).context("frame truncated inside the length prefix")?;
    let len = u32::from_be_bytes(prefix) as usize;
    ensure!(len <= cap, "oversized frame: {len} bytes exceeds the {cap}-byte cap");
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .with_context(|| format!("frame truncated: expected {len} payload bytes"))?;
    Ok(FrameEvent::Frame(payload))
}

/// Blocking frame read: `Ok(None)` on clean EOF at a frame boundary.
/// (On a stream without a read timeout, `Idle` cannot occur.)
pub fn read_frame(r: &mut impl Read, cap: usize) -> crate::Result<Option<Vec<u8>>> {
    match read_frame_event(r, cap)? {
        FrameEvent::Frame(payload) => Ok(Some(payload)),
        FrameEvent::Closed => Ok(None),
        FrameEvent::Idle => bail!("read timed out waiting for a frame"),
    }
}

/// Write one [`Message`] as a frame.
pub fn write_message(w: &mut impl Write, msg: &Message) -> crate::Result<()> {
    write_frame(w, msg.to_json().render().as_bytes())
        .with_context(|| format!("sending '{}'", msg.tag()))
}

/// Read one [`Message`]; `Ok(None)` on clean EOF.
pub fn read_message(r: &mut impl Read, cap: usize) -> crate::Result<Option<Message>> {
    match read_frame(r, cap)? {
        None => Ok(None),
        Some(bytes) => Ok(Some(Message::from_json(&Json::parse_slice(&bytes)?)?)),
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Socket-server tuning knobs.
#[derive(Clone, Debug)]
pub struct TcpServerConfig {
    /// Silence window after which a worker is declared dead and its
    /// in-flight requests requeued.
    pub dead_after: Duration,
    /// Jobs pipelined per worker connection: the feeder keeps up to this
    /// many requests in flight on one socket, so a worker never sits
    /// idle waiting for the next dispatch round-trip (`0` is treated as
    /// `1`).
    pub capacity: usize,
    /// Fraction of worker-claimed results the server re-verifies itself
    /// by differential replay (`0.0` = trust workers, `1.0` = audit
    /// everything). Results whose claimed validation record does not
    /// reproduce are rejected.
    pub audit_fraction: f64,
}

impl Default for TcpServerConfig {
    fn default() -> Self {
        TcpServerConfig { dead_after: DEFAULT_DEAD_AFTER, capacity: 1, audit_fraction: 0.0 }
    }
}

type SharedWriter = Arc<Mutex<TcpStream>>;

/// Routes completed responses back to the client connection that
/// submitted them. Responses whose client disconnected are dropped
/// (their side effects — metrics, verification — already happened).
#[derive(Default)]
struct Router {
    pending: Mutex<HashMap<u64, SharedWriter>>,
}

impl Router {
    fn register(&self, id: u64, writer: SharedWriter) {
        self.pending.lock().unwrap().insert(id, writer);
    }

    fn deregister(&self, id: u64) {
        self.pending.lock().unwrap().remove(&id);
    }

    fn route(&self, resp: PartitionResponse) {
        let writer = self.pending.lock().unwrap().remove(&resp.id);
        if let Some(writer) = writer {
            let mut w = writer.lock().unwrap();
            let _ = write_message(&mut *w, &Message::Response(resp));
        }
    }
}

/// One registered remote worker, as the server sees it.
struct RemoteWorker {
    id: u64,
    name: String,
    /// Pipelining depth: how many jobs may sit in `in_flight` at once.
    capacity: usize,
    /// Every request dispatched to this worker whose result has not
    /// arrived, keyed by request id. Draining the map under the lock is
    /// the exactly-once requeue guard: whichever of the feeder or reader
    /// observes the death first takes all of them.
    in_flight: Mutex<HashMap<u64, PartitionRequest>>,
    /// Signals the feeder when a slot frees (result arrived) or the
    /// worker dies.
    idle_cv: Condvar,
    dead: AtomicBool,
    last_seen: Mutex<Instant>,
}

impl RemoteWorker {
    fn mark_dead(&self) {
        self.dead.store(true, Ordering::Relaxed);
        self.idle_cv.notify_all();
    }

    fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Relaxed)
    }

    /// Requeue every in-flight request — each exactly once, and at most
    /// [`MAX_REQUEUES`] times per request: a request that keeps killing
    /// workers is failed back to its client instead of taking down the
    /// fleet.
    fn requeue_in_flight(&self, shared: &ServiceShared) {
        let mut taken: Vec<PartitionRequest> = {
            let mut slots = self.in_flight.lock().unwrap();
            slots.drain().map(|(_, req)| req).collect()
        };
        if taken.is_empty() {
            return;
        }
        // Requeue newest first: each push goes to the queue's front, so
        // the *oldest* dispatched request ends up at the very head and
        // head-of-line priority survives a multi-job worker death.
        taken.sort_by_key(|r| std::cmp::Reverse(r.id));
        for req in taken {
            let id = req.id;
            let attempts = {
                let mut counts = shared.requeue_counts.lock().unwrap();
                let c = counts.entry(id).or_insert(0);
                *c += 1;
                *c
            };
            if attempts > MAX_REQUEUES {
                eprintln!(
                    "[serve] request {id} was in flight on {attempts} workers that died — \
                     failing it (poison request?)"
                );
                let resp = PartitionResponse {
                    id,
                    request: req,
                    result: Err(anyhow!(
                        "request {id} was in flight on {attempts} workers that died; \
                         giving up after {MAX_REQUEUES} requeues"
                    )),
                    rejected: false,
                };
                // The shared terminal path clears the requeue ledger
                // entry and accounts the failure.
                shared.complete_response(&resp);
                if let Some(tx) = shared.response_sender() {
                    let _ = tx.send(resp);
                }
            } else {
                shared.metrics.record_requeue();
                if shared.queue.push_front(req) {
                    eprintln!(
                        "[serve] worker #{} ({}) died with request {id} in flight — requeued \
                         (attempt {attempts}/{MAX_REQUEUES})",
                        self.id, self.name
                    );
                } else {
                    // Shutdown race: the queue is closed. The request
                    // reaches no other terminal path, so its ledger
                    // entry must be cleared here or it leaks.
                    shared.metrics.record_unqueue();
                    shared.requeue_counts.lock().unwrap().remove(&id);
                }
            }
        }
        self.idle_cv.notify_all();
    }
}

/// The socket front of a [`Service`]: accepts worker registrations and
/// client submissions, dispatches the shared queue to live workers, and
/// answers `status` requests with the coordinator metrics.
pub struct TcpServer {
    shared: Arc<ServiceShared>,
    pub metrics: Arc<Metrics>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    router_thread: Option<JoinHandle<()>>,
    local_workers: Vec<JoinHandle<()>>,
}

impl TcpServer {
    /// Put `svc` behind `listener`. The service's local worker threads
    /// (if any) keep serving the queue alongside remote workers.
    pub fn start(
        svc: Service,
        listener: TcpListener,
        cfg: TcpServerConfig,
    ) -> crate::Result<TcpServer> {
        let Service { shared, responses, metrics, workers: local_workers } = svc;
        shared.attach_transport();
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let router = Arc::new(Router::default());

        let router_thread = std::thread::spawn({
            let router = Arc::clone(&router);
            move || {
                // Ends when every response sender is gone (shutdown).
                for resp in responses.iter() {
                    router.route(resp);
                }
            }
        });

        let accept_thread = std::thread::spawn({
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            move || accept_loop(listener, shared, router, stop, cfg)
        });

        Ok(TcpServer {
            metrics,
            shared,
            addr,
            stop,
            accept_thread: Some(accept_thread),
            router_thread: Some(router_thread),
            local_workers,
        })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Requeue-ledger entries still outstanding (0 once every dispatched
    /// request reached a terminal path — tests assert this after the
    /// poison-request scenario).
    pub fn pending_requeue_entries(&self) -> usize {
        self.shared.pending_requeue_entries()
    }

    /// Block on the accept loop — the CLI server mode runs here until
    /// the process is killed.
    pub fn join(mut self) -> crate::Result<()> {
        if let Some(t) = self.accept_thread.take() {
            t.join().map_err(|_| anyhow!("accept loop panicked"))?;
        }
        Ok(())
    }

    /// Stop accepting, close the queue (draining jobs complete), close
    /// worker sockets, and join the service threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.shared.queue.close();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for w in self.local_workers.drain(..) {
            let _ = w.join();
        }
        // Release the master response sender so the router drains out
        // once the last connection thread drops its clone.
        self.shared.take_response_sender();
        if let Some(t) = self.router_thread.take() {
            let _ = t.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<ServiceShared>,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    cfg: TcpServerConfig,
) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, peer)) => {
                let shared = Arc::clone(&shared);
                let router = Arc::clone(&router);
                let cfg = cfg.clone();
                std::thread::spawn(move || {
                    // A connection failing — malformed frames, protocol
                    // violations, abrupt closes — must never take the
                    // listener down with it.
                    handle_connection(stream, peer, shared, router, cfg);
                });
            }
            // Non-blocking accept: poll so the stop flag is honored.
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(50)),
        }
    }
}

fn send_error(writer: &SharedWriter, message: &str) {
    let mut w = writer.lock().unwrap();
    let _ = write_message(&mut *w, &Message::Error { message: message.to_string() });
}

fn handle_connection(
    stream: TcpStream,
    peer: SocketAddr,
    shared: Arc<ServiceShared>,
    router: Arc<Router>,
    cfg: TcpServerConfig,
) {
    stream.set_nodelay(true).ok();
    let mut reader = match stream.try_clone() {
        Ok(r) => r,
        Err(_) => return,
    };
    let writer: SharedWriter = Arc::new(Mutex::new(stream));
    // The first frame declares the peer's role.
    match read_message(&mut reader, MAX_FRAME_LEN) {
        Ok(Some(Message::Register { name })) => worker_connection(name, reader, writer, shared, cfg),
        Ok(Some(first @ (Message::Submit(_) | Message::Status | Message::Metrics))) => {
            client_connection(first, reader, writer, shared, router)
        }
        Ok(Some(other)) => send_error(
            &writer,
            &format!(
                "protocol error: expected register, submit, status or metrics, got '{}'",
                other.tag()
            ),
        ),
        Ok(None) => {}
        Err(e) => {
            eprintln!("[serve] rejecting {peer}: {e:#}");
            send_error(&writer, &format!("bad frame: {e:#}"));
        }
    }
}

// ---- worker connections ---------------------------------------------------

fn worker_connection(
    name: String,
    reader: TcpStream,
    writer: SharedWriter,
    shared: Arc<ServiceShared>,
    cfg: TcpServerConfig,
) {
    let id = shared.next_worker_id.fetch_add(1, Ordering::Relaxed);
    // Grab the response channel before counting the worker as connected,
    // so an early return cannot leave the workers gauge inflated.
    let Some(resp_tx) = shared.response_sender() else {
        return; // shutting down
    };
    {
        let mut w = writer.lock().unwrap();
        if write_message(&mut *w, &Message::Registered { worker_id: id }).is_err() {
            return;
        }
    }
    shared.metrics.record_worker_connected();
    eprintln!("[serve] worker #{id} ({name}) registered");
    let entry = shared.register_worker(id, name.clone(), cfg.capacity.max(1) as u64);
    let worker = Arc::new(RemoteWorker {
        id,
        name,
        capacity: cfg.capacity.max(1),
        in_flight: Mutex::new(HashMap::new()),
        idle_cv: Condvar::new(),
        dead: AtomicBool::new(false),
        last_seen: Mutex::new(Instant::now()),
    });

    let feeder = std::thread::spawn({
        let worker = Arc::clone(&worker);
        let entry = Arc::clone(&entry);
        let shared = Arc::clone(&shared);
        let writer = Arc::clone(&writer);
        move || feeder_loop(&worker, &entry, &writer, &shared)
    });
    reader_loop(&worker, &entry, reader, &shared, resp_tx, &cfg);
    // Reader exited (death, protocol violation, or shutdown): make sure
    // the feeder unblocks and any in-flight request survives.
    worker.mark_dead();
    worker.requeue_in_flight(&shared);
    let _ = feeder.join();
    shared.deregister_worker(id);
    shared.metrics.record_worker_lost();
    eprintln!("[serve] worker #{} ({}) disconnected", worker.id, worker.name);
}

/// Pulls jobs off the shared queue and ships them to one worker,
/// keeping up to `worker.capacity` requests pipelined on the socket:
/// the worker process consumes them sequentially, but the next job is
/// already buffered when a result comes back, so a multi-job worker
/// never idles on the dispatch round-trip.
fn feeder_loop(
    worker: &RemoteWorker,
    entry: &WorkerEntry,
    writer: &SharedWriter,
    shared: &ServiceShared,
) {
    loop {
        // Wait for a free slot (a result arrived) or death.
        {
            let mut slots = worker.in_flight.lock().unwrap();
            while slots.len() >= worker.capacity && !worker.is_dead() {
                slots = worker
                    .idle_cv
                    .wait_timeout(slots, Duration::from_millis(100))
                    .unwrap()
                    .0;
            }
        }
        if worker.is_dead() {
            break;
        }
        match shared.queue.pop_timeout(Duration::from_millis(100)) {
            Popped::Closed => {
                // Shutdown: close the socket so the worker process sees
                // EOF and exits cleanly.
                let _ = writer.lock().unwrap().shutdown(Shutdown::Both);
                break;
            }
            Popped::Empty => continue,
            Popped::Job(req) => {
                shared.note_dispatch(req.id);
                let depth = {
                    let mut slots = worker.in_flight.lock().unwrap();
                    slots.insert(req.id, req.clone());
                    slots.len()
                };
                entry.in_flight.store(depth as u64, Ordering::Relaxed);
                let sent = {
                    let mut w = writer.lock().unwrap();
                    write_message(&mut *w, &Message::Job(req)).is_ok()
                };
                if !sent {
                    worker.mark_dead();
                    worker.requeue_in_flight(shared);
                    break;
                }
            }
        }
    }
    // Safety net (exactly-once via the map drain).
    worker.requeue_in_flight(shared);
}

/// Consumes one worker's frames: heartbeats refresh liveness, results
/// free their in-flight slot, run the sampled server-side audit, and
/// flow into the shared response channel. Returns when the worker is
/// dead by any definition.
fn reader_loop(
    worker: &RemoteWorker,
    entry: &WorkerEntry,
    mut reader: TcpStream,
    shared: &ServiceShared,
    resp_tx: Sender<PartitionResponse>,
    cfg: &TcpServerConfig,
) {
    let dead_after = cfg.dead_after;
    // Deterministic per-connection sampler: worker id seeds it, so test
    // runs with a fixed fleet audit reproducibly.
    let mut audit_rng = Rng::new(0xA0D1_7000 ^ worker.id);
    // Wake at least a few times per dead_after window to check liveness;
    // a timeout before a frame's first byte is just "quiet", mid-frame
    // it means the peer stalled (handled as an error below).
    let poll = (dead_after / 4).max(Duration::from_millis(50));
    if reader.set_read_timeout(Some(poll)).is_err() {
        return;
    }
    loop {
        match read_frame_event(&mut reader, MAX_FRAME_LEN) {
            Ok(FrameEvent::Frame(bytes)) => {
                *worker.last_seen.lock().unwrap() = Instant::now();
                entry.touch();
                let msg = match Json::parse_slice(&bytes)
                    .map_err(anyhow::Error::from)
                    .and_then(|j| Message::from_json(&j))
                {
                    Ok(m) => m,
                    Err(e) => {
                        eprintln!("[serve] worker #{}: bad frame: {e:#}", worker.id);
                        return;
                    }
                };
                match msg {
                    Message::Heartbeat => {}
                    Message::Result(resp) => {
                        let matched = {
                            let mut slots = worker.in_flight.lock().unwrap();
                            let hit = slots.remove(&resp.id).is_some();
                            if hit {
                                entry.in_flight.store(slots.len() as u64, Ordering::Relaxed);
                                worker.idle_cv.notify_all();
                            }
                            hit
                        };
                        if matched {
                            entry.completed.fetch_add(1, Ordering::Relaxed);
                            // The worker measured its own search; feed it
                            // into the search_cold histogram so socket
                            // mode reports the same latency phases the
                            // thread mode does.
                            if let Ok(sol) = &resp.result {
                                shared.metrics.record_search_latency(
                                    Duration::from_secs_f64(sol.search_time_s),
                                );
                            }
                            // Sampled server-side audit *before* the
                            // terminal path: a rejected result must
                            // never enter the solution cache.
                            let resp = if cfg.audit_fraction > 0.0
                                && audit_rng.f64() < cfg.audit_fraction
                            {
                                audit_response(resp, shared, worker.id)
                            } else {
                                resp
                            };
                            // Shared terminal path: cache insert, requeue
                            // ledger clear, metrics.
                            shared.complete_response(&resp);
                            let _ = resp_tx.send(resp);
                        } else {
                            eprintln!(
                                "[serve] worker #{}: stray result for request {} — dropped",
                                worker.id, resp.id
                            );
                        }
                    }
                    other => {
                        eprintln!(
                            "[serve] worker #{}: unexpected '{}' — closing",
                            worker.id,
                            other.tag()
                        );
                        return;
                    }
                }
            }
            Ok(FrameEvent::Idle) => {
                let silent = worker.last_seen.lock().unwrap().elapsed();
                if silent > dead_after {
                    eprintln!(
                        "[serve] worker #{}: no heartbeat for {silent:?} — declaring dead",
                        worker.id
                    );
                    return;
                }
            }
            Ok(FrameEvent::Closed) => return,
            Err(_) => return,
        }
        if worker.is_dead() {
            return;
        }
    }
}

/// Server-side sampled re-verification. Workers run their own
/// differential replay, so a Byzantine worker could return a fabricated
/// [`crate::api::ValidationRecord`] (or a spec that was never executed
/// at all) and the claim would flow to the client unchallenged. For a
/// sampled result the server replays the spec through the same
/// differential harness itself — deterministic given the record's seed,
/// so an honest worker's record reproduces byte for byte — and converts
/// any result whose claim does not reproduce into a rejection.
fn audit_response(
    resp: PartitionResponse,
    shared: &ServiceShared,
    worker_id: u64,
) -> PartitionResponse {
    let Ok(sol) = &resp.result else {
        return resp; // failures carry no verification claim to audit
    };
    let claimed = sol.validation.clone();
    // Nothing claimed and nothing owed (the request opted out of
    // verification): there is no claim to challenge.
    if claimed.is_none() && !(shared.cfg.verify && resp.request.verify) {
        return resp;
    }
    shared.metrics.record_audited();
    let compiled = match shared.models.resolve(&resp.request.model) {
        Ok(c) => c,
        Err(e) => {
            return reject_audited(
                resp,
                &format!("its model does not compile on the server: {e:#}"),
                shared,
                worker_id,
            );
        }
    };
    if !compiled.interpreter_sized() {
        return if claimed.is_some() {
            // Thread mode never attaches a record to IR it cannot
            // execute — a claim here is inherently unverifiable forgery.
            reject_audited(
                resp,
                "it claims a validation record for a model too large to replay",
                shared,
                worker_id,
            )
        } else {
            resp // verification exempt, same as the worker-side gate
        };
    }
    let seed = claimed.as_ref().map_or(shared.cfg.verify_seed, |v| v.seed);
    let t_verify = Instant::now();
    let replay = {
        let _sp = crate::obs::span("service", "request.audit");
        match &sol.stages {
            Some(sa) => validate_staged_solution_spec(
                compiled.func(),
                &sol.spec,
                sa,
                &resp.request.mesh,
                seed,
            ),
            None => validate_solution_spec(compiled.func(), &sol.spec, &resp.request.mesh, seed),
        }
    };
    shared.metrics.record_verify_latency(t_verify.elapsed());
    match replay {
        Ok(record) if record.pass => {
            // The spec replays clean. Stamp the *server's* record onto
            // the response so even the numbers are server-attested —
            // byte-identical to an honest worker's record, since the
            // replay is deterministic in (spec, mesh, seed).
            let mut resp = resp;
            if let Ok(sol) = &mut resp.result {
                sol.validation = Some(record);
            }
            resp
        }
        Ok(record) => reject_audited(
            resp,
            &format!(
                "its claimed validation does not reproduce: max relative divergence \
                 {:.3e} exceeds tol {:.1e}",
                record.max_rel_err, record.tol
            ),
            shared,
            worker_id,
        ),
        Err(e) => reject_audited(
            resp,
            &format!("its claimed validation does not replay: {e:#}"),
            shared,
            worker_id,
        ),
    }
}

/// Convert an audited result that failed re-verification into a
/// rejection (counted in [`Metrics::audit_rejected`]).
fn reject_audited(
    resp: PartitionResponse,
    why: &str,
    shared: &ServiceShared,
    worker_id: u64,
) -> PartitionResponse {
    shared.metrics.record_audit_rejected();
    eprintln!(
        "[serve] audit: rejecting request {} from worker #{worker_id}: {why}",
        resp.id
    );
    PartitionResponse {
        id: resp.id,
        result: Err(anyhow!(
            "server-side audit rejected request {} from worker #{worker_id}: {why}",
            resp.id
        )),
        request: resp.request,
        rejected: true,
    }
}

// ---- client connections ---------------------------------------------------

fn client_connection(
    first: Message,
    mut reader: TcpStream,
    writer: SharedWriter,
    shared: Arc<ServiceShared>,
    router: Arc<Router>,
) {
    let mut my_ids: Vec<u64> = Vec::new();
    let mut next = Some(first);
    loop {
        let msg = match next.take() {
            Some(m) => m,
            None => match read_message(&mut reader, MAX_FRAME_LEN) {
                Ok(Some(m)) => m,
                Ok(None) => break,
                Err(e) => {
                    send_error(&writer, &format!("bad frame: {e:#}"));
                    break;
                }
            },
        };
        match msg {
            Message::Submit(mut req) => {
                let id = shared.allocate_id();
                req.id = id;
                // Register the route *before* admission: a fast worker
                // may answer before this thread runs again.
                router.register(id, Arc::clone(&writer));
                match shared.admit(req) {
                    Ok(None) => {
                        my_ids.push(id);
                        let mut w = writer.lock().unwrap();
                        if write_message(&mut *w, &Message::Submitted { id }).is_err() {
                            break;
                        }
                    }
                    Ok(Some(resp)) => {
                        // Cache hit: ack, then answer on this connection
                        // immediately — no queue, no worker, no router.
                        router.deregister(id);
                        let mut w = writer.lock().unwrap();
                        if write_message(&mut *w, &Message::Submitted { id }).is_err()
                            || write_message(&mut *w, &Message::Response(resp)).is_err()
                        {
                            break;
                        }
                    }
                    Err(e) => {
                        router.deregister(id);
                        if let Some(o) = e.downcast_ref::<Overloaded>() {
                            // Structured backpressure, not a hard error:
                            // the client may retry after draining.
                            let mut w = writer.lock().unwrap();
                            let msg = Message::Overloaded { queued: o.queued, limit: o.limit };
                            if write_message(&mut *w, &msg).is_err() {
                                break;
                            }
                        } else {
                            send_error(&writer, &format!("{e:#}"));
                        }
                    }
                }
            }
            Message::Status => {
                let report = shared.status_report();
                let mut w = writer.lock().unwrap();
                if write_message(&mut *w, &Message::StatusReport(report)).is_err() {
                    break;
                }
            }
            Message::Metrics => {
                let text = shared.prometheus_text();
                let mut w = writer.lock().unwrap();
                if write_message(&mut *w, &Message::MetricsReport { text }).is_err() {
                    break;
                }
            }
            other => {
                send_error(&writer, &format!("unexpected message '{}'", other.tag()));
                break;
            }
        }
    }
    // Responses for requests this client abandoned are dropped at the
    // router instead of piling up against a dead socket.
    for id in my_ids {
        router.deregister(id);
    }
}

// ---------------------------------------------------------------------------
// Worker process loop
// ---------------------------------------------------------------------------

/// Worker-process options: a display name plus the same [`ServiceConfig`]
/// the in-process workers run with (`workers` is ignored; `verify`,
/// `verify_seed` and `search_threads` steer [`process_request`] exactly
/// as they do in thread mode).
#[derive(Clone, Debug)]
pub struct WorkerOptions {
    pub name: String,
    pub service: ServiceConfig,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions {
            name: format!("worker-{}", std::process::id()),
            service: ServiceConfig::default(),
        }
    }
}

/// Connect to a server and serve jobs until it closes the socket.
pub fn run_worker(addr: &str, opts: &WorkerOptions) -> crate::Result<()> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting worker to {addr}"))?;
    run_worker_on(stream, opts)
}

/// Reconnect policy for [`run_worker_reconnect`]: exponential backoff
/// between attempts, giving up after `max_attempts` *consecutive* failed
/// connection attempts (a successful connect resets both the counter and
/// the delay).
#[derive(Clone, Debug)]
pub struct ReconnectPolicy {
    /// First retry delay after a failed connect or a lost session.
    pub initial: Duration,
    /// Backoff cap (delays double up to this).
    pub max: Duration,
    /// Consecutive failed connection attempts before giving up;
    /// `0` retries forever.
    pub max_attempts: u32,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        ReconnectPolicy {
            initial: Duration::from_millis(100),
            max: Duration::from_secs(5),
            max_attempts: 10,
        }
    }
}

/// [`run_worker`] with reconnect: when the connection is lost — the
/// server was killed, restarted, or closed the socket — retry with
/// exponential backoff instead of exiting, so a restarted server picks
/// its fleet back up without anyone re-spawning worker processes. The
/// per-process [`ModelCache`] would be rebuilt per session either way;
/// what survives is the *process* and its place in the operator's
/// supervision tree.
pub fn run_worker_reconnect(
    addr: &str,
    opts: &WorkerOptions,
    policy: &ReconnectPolicy,
) -> crate::Result<()> {
    let mut delay = policy.initial;
    let mut failures: u32 = 0;
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                let t0 = Instant::now();
                let outcome = run_worker_on(stream, opts);
                // Only a session that actually lived (outlasted the
                // backoff cap) resets the counters: a connect that is
                // immediately rejected — wrong endpoint, protocol
                // mismatch — must keep backing off and eventually give
                // up, or `max_attempts` would be unreachable.
                if t0.elapsed() >= policy.max {
                    failures = 0;
                    delay = policy.initial;
                } else {
                    failures += 1;
                }
                match outcome {
                    Ok(()) => eprintln!(
                        "[worker] {}: server closed the connection; reconnecting to {addr}",
                        opts.name
                    ),
                    Err(e) => eprintln!(
                        "[worker] {}: session ended ({e:#}); reconnecting to {addr}",
                        opts.name
                    ),
                }
                if policy.max_attempts > 0 && failures >= policy.max_attempts {
                    bail!(
                        "giving up on {addr} after {failures} consecutive short-lived \
                         sessions or failed connection attempts"
                    );
                }
            }
            Err(e) => {
                failures += 1;
                if policy.max_attempts > 0 && failures >= policy.max_attempts {
                    bail!(
                        "giving up on {addr} after {failures} consecutive failed \
                         connection attempts: {e}"
                    );
                }
                eprintln!(
                    "[worker] {}: connect to {addr} failed ({e}); retry {failures} in {delay:?}",
                    opts.name
                );
            }
        }
        std::thread::sleep(delay);
        delay = (delay * 2).min(policy.max);
    }
}

/// The worker loop over an established stream: register, heartbeat in
/// the background, and run [`process_request`] — the compiled-model
/// cache + differential-replay path shared with the in-process threads —
/// for every job. Returns `Ok(())` when the server closes the
/// connection.
pub fn run_worker_on(stream: TcpStream, opts: &WorkerOptions) -> crate::Result<()> {
    stream.set_nodelay(true).ok();
    // Bounded writes: a dead or wedged server socket fails heartbeat and
    // result writes within the timeout instead of blocking forever —
    // without this, the heartbeat thread could pin `heartbeat.join()`
    // and stall a reconnect cycle indefinitely.
    stream.set_write_timeout(Some(WORKER_WRITE_TIMEOUT)).ok();
    let mut reader = stream.try_clone()?;
    let writer = Arc::new(Mutex::new(stream));
    {
        let mut w = writer.lock().unwrap();
        write_message(&mut *w, &Message::Register { name: opts.name.clone() })?;
    }
    let worker_id = match read_message(&mut reader, MAX_FRAME_LEN)? {
        Some(Message::Registered { worker_id }) => worker_id,
        Some(Message::Error { message }) => bail!("server rejected registration: {message}"),
        Some(other) => bail!("expected registration ack, got '{}'", other.tag()),
        None => bail!("server closed the connection during registration"),
    };
    eprintln!("[worker] {} registered as #{worker_id}", opts.name);

    // Heartbeats flow from a dedicated thread so a long search cannot
    // silence them — the server must be able to tell "busy" from "dead".
    // Shutdown is a (flag, condvar) pair instead of a bare sleep loop:
    // the main loop's notify wakes the thread *immediately*, so
    // `heartbeat.join()` below never stalls a reconnect cycle for up to
    // a heartbeat interval (or, with the write timeout above, spins
    // writes against a socket the session already abandoned).
    let stop = Arc::new((Mutex::new(false), Condvar::new()));
    let heartbeat = std::thread::spawn({
        let writer = Arc::clone(&writer);
        let stop = Arc::clone(&stop);
        move || {
            let (flag, cv) = &*stop;
            loop {
                {
                    let guard = flag.lock().unwrap();
                    let (guard, _) = cv.wait_timeout(guard, HEARTBEAT_INTERVAL).unwrap();
                    if *guard {
                        break;
                    }
                }
                let mut w = writer.lock().unwrap();
                if write_message(&mut *w, &Message::Heartbeat).is_err() {
                    break;
                }
            }
        }
    });

    let models = ModelCache::default();
    let result = (|| {
        loop {
            match read_message(&mut reader, MAX_FRAME_LEN)? {
                None => return Ok(()), // server closed: clean exit
                Some(Message::Job(req)) => {
                    eprintln!(
                        "[worker] #{worker_id}: request {} ({} via {})",
                        req.id,
                        req.model.name(),
                        req.method.name()
                    );
                    let resp = process_request(&req, &models, &opts.service);
                    let mut w = writer.lock().unwrap();
                    write_message(&mut *w, &Message::Result(resp))?;
                }
                Some(other) => bail!("unexpected message '{}' from server", other.tag()),
            }
        }
    })();
    // Signal, wake, then join: the condvar wakes the heartbeat thread
    // immediately instead of letting it sleep out its interval.
    let (flag, cv) = &*stop;
    *flag.lock().unwrap() = true;
    cv.notify_all();
    let _ = heartbeat.join();
    result
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// A submit/status client over one connection. Responses arrive in
/// completion order and may interleave with acks, so reads buffer
/// out-of-band responses instead of assuming strict alternation.
pub struct ServiceClient {
    reader: TcpStream,
    writer: TcpStream,
    buffered: std::collections::VecDeque<PartitionResponse>,
}

impl ServiceClient {
    pub fn connect(addr: &str) -> crate::Result<ServiceClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting client to {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(ServiceClient {
            reader: stream.try_clone()?,
            writer: stream,
            buffered: std::collections::VecDeque::new(),
        })
    }

    fn next_message(&mut self) -> crate::Result<Message> {
        read_message(&mut self.reader, MAX_FRAME_LEN)?
            .ok_or_else(|| anyhow!("server closed the connection"))
    }

    /// Submit a request; returns the id the server assigned. An
    /// admission-control refusal surfaces as an [`Overloaded`] error
    /// (downcastable), distinguishable from hard failures so callers can
    /// back off and retry.
    pub fn submit(&mut self, req: PartitionRequest) -> crate::Result<u64> {
        write_message(&mut self.writer, &Message::Submit(req))?;
        loop {
            match self.next_message()? {
                Message::Submitted { id } => return Ok(id),
                Message::Response(resp) => self.buffered.push_back(resp),
                Message::Overloaded { queued, limit } => {
                    return Err(anyhow::Error::new(Overloaded { queued, limit }))
                }
                Message::Error { message } => bail!("server refused the submission: {message}"),
                other => bail!("unexpected '{}' while awaiting submission ack", other.tag()),
            }
        }
    }

    /// Receive the next completed response (blocking).
    pub fn recv_response(&mut self) -> crate::Result<PartitionResponse> {
        if let Some(resp) = self.buffered.pop_front() {
            return Ok(resp);
        }
        loop {
            match self.next_message()? {
                Message::Response(resp) => return Ok(resp),
                Message::Error { message } => bail!("server error: {message}"),
                other => bail!("unexpected '{}' while awaiting a response", other.tag()),
            }
        }
    }

    /// Fetch the server's metrics counters.
    pub fn status(&mut self) -> crate::Result<StatusReport> {
        write_message(&mut self.writer, &Message::Status)?;
        loop {
            match self.next_message()? {
                Message::StatusReport(report) => return Ok(report),
                Message::Response(resp) => self.buffered.push_back(resp),
                Message::Error { message } => bail!("server error: {message}"),
                other => bail!("unexpected '{}' while awaiting status", other.tag()),
            }
        }
    }

    /// Fetch the server's Prometheus text exposition (`toast status
    /// --prom` serves this verbatim to a scrape).
    pub fn metrics_prom(&mut self) -> crate::Result<String> {
        write_message(&mut self.writer, &Message::Metrics)?;
        loop {
            match self.next_message()? {
                Message::MetricsReport { text } => return Ok(text),
                Message::Response(resp) => self.buffered.push_back(resp),
                Message::Error { message } => bail!("server error: {message}"),
                other => bail!("unexpected '{}' while awaiting metrics", other.tag()),
            }
        }
    }
}

/// Bind `addr`, print the resolved address (CI parses `listening on
/// HOST:PORT` off stdout), and serve until killed. The in-process worker
/// threads configured by `svc_cfg.workers` (commonly 0 in socket mode)
/// run alongside any workers that connect.
pub fn serve_listen(
    addr: &str,
    svc_cfg: ServiceConfig,
    tcp_cfg: TcpServerConfig,
) -> crate::Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    println!("listening on {}", listener.local_addr()?);
    std::io::stdout().flush().ok();
    let svc = Service::start_with(svc_cfg);
    let server = TcpServer::start(svc, listener, tcp_cfg)?;
    server.join()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    /// A reader that hands out at most one byte per `read` call —
    /// maximal fragmentation, the worst case for a framing codec.
    struct Dribble<R> {
        inner: R,
    }

    impl<R: Read> Read for Dribble<R> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if buf.is_empty() {
                return Ok(0);
            }
            self.inner.read(&mut buf[..1])
        }
    }

    #[test]
    fn frames_roundtrip_even_one_byte_at_a_time() {
        let payloads: [&[u8]; 4] =
            [b"", b"x", br#"{"msg":"heartbeat"}"#, &[0u8; 4096]];
        for payload in payloads {
            let mut wire = Vec::new();
            write_frame(&mut wire, payload).unwrap();
            assert_eq!(wire.len(), 4 + payload.len());
            let mut r = Dribble { inner: Cursor::new(wire) };
            let back = read_frame(&mut r, MAX_FRAME_LEN).unwrap().expect("frame");
            assert_eq!(back, payload);
            assert!(read_frame(&mut r, MAX_FRAME_LEN).unwrap().is_none(), "clean EOF");
        }
    }

    #[test]
    fn several_frames_in_one_stream() {
        let mut wire = Vec::new();
        for i in 0..10u8 {
            write_frame(&mut wire, &vec![i; i as usize]).unwrap();
        }
        let mut r = Dribble { inner: Cursor::new(wire) };
        for i in 0..10u8 {
            assert_eq!(read_frame(&mut r, MAX_FRAME_LEN).unwrap().unwrap(), vec![i; i as usize]);
        }
        assert!(read_frame(&mut r, MAX_FRAME_LEN).unwrap().is_none());
    }

    #[test]
    fn oversized_frames_are_rejected_without_reading_the_payload() {
        // Garbage prefix decoding to ~4 GiB: rejected immediately.
        let mut r = Cursor::new(vec![0xFF, 0xFF, 0xFF, 0xFF]);
        let err = read_frame(&mut r, MAX_FRAME_LEN).unwrap_err();
        assert!(format!("{err:#}").contains("oversized"), "{err:#}");
        // And the encoder refuses to build one in the first place.
        let too_big = vec![0u8; MAX_FRAME_LEN + 1];
        assert!(encode_frame(&too_big).is_err());
        // A frame just over a small cap is rejected too.
        let mut wire = Vec::new();
        write_frame(&mut wire, &[7u8; 100]).unwrap();
        let err = read_frame(&mut Cursor::new(wire), 64).unwrap_err();
        assert!(format!("{err:#}").contains("oversized"), "{err:#}");
    }

    #[test]
    fn truncated_frames_error_instead_of_hanging() {
        // Prefix promises 100 bytes, stream ends after 3.
        let mut wire = Vec::new();
        wire.extend_from_slice(&100u32.to_be_bytes());
        wire.extend_from_slice(b"abc");
        let err = read_frame(&mut Cursor::new(wire), MAX_FRAME_LEN).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        // EOF inside the length prefix itself.
        let err = read_frame(&mut Cursor::new(vec![0u8, 0]), MAX_FRAME_LEN).unwrap_err();
        assert!(format!("{err:#}").contains("length prefix"), "{err:#}");
    }

    #[test]
    fn message_frames_roundtrip() {
        let mut wire = Vec::new();
        write_message(&mut wire, &Message::Register { name: "w".into() }).unwrap();
        write_message(&mut wire, &Message::Heartbeat).unwrap();
        write_message(&mut wire, &Message::Submitted { id: 3 }).unwrap();
        let mut r = Dribble { inner: Cursor::new(wire) };
        assert!(matches!(
            read_message(&mut r, MAX_FRAME_LEN).unwrap(),
            Some(Message::Register { .. })
        ));
        assert!(matches!(read_message(&mut r, MAX_FRAME_LEN).unwrap(), Some(Message::Heartbeat)));
        assert!(matches!(
            read_message(&mut r, MAX_FRAME_LEN).unwrap(),
            Some(Message::Submitted { id: 3 })
        ));
        assert!(read_message(&mut r, MAX_FRAME_LEN).unwrap().is_none());
    }

    #[test]
    fn non_json_payloads_are_an_error_not_a_panic() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"definitely not json").unwrap();
        assert!(read_message(&mut Cursor::new(wire), MAX_FRAME_LEN).is_err());
        let mut wire = Vec::new();
        write_frame(&mut wire, &[0xFF, 0xFE]).unwrap(); // invalid UTF-8
        assert!(read_message(&mut Cursor::new(wire), MAX_FRAME_LEN).is_err());
    }
}
