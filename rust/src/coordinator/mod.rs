//! L3 coordinator: the partition service, its two transports, its
//! metrics, and the experiment runners that regenerate the paper's
//! figures.
//!
//! TOAST is a compiler-side system, so the coordinator's job is a
//! partition-request service: clients submit `(model-source, mesh,
//! hardware, method, budget)` requests — the model is a zoo name *or* a
//! serialized `Func` — a worker pool resolves each to a shared
//! [`crate::api::CompiledModel`] (analysis runs once per model, not per
//! request), runs the strategy, and returns a serializable
//! [`crate::api::PartitionResponse`]. Accepted specs are replayed
//! through the differential harness before the service trusts them
//! (trust-but-verify; see [`service`]).
//!
//! Two transports, one dispatch/verify path: the default in-process
//! thread pool ([`Service`]) and the socket mode ([`transport`]) — a
//! length-prefixed JSON wire protocol over TCP behind `toast serve
//! --listen`, with workers as OS processes (`toast worker --connect`)
//! and a submit/status client (`toast submit --connect`). Both pull the
//! same [`service::JobQueue`], both run [`service::process_request`],
//! and both account through [`metrics::Metrics::record_response`].

pub mod experiments;
pub mod metrics;
pub mod service;
pub mod transport;

pub use experiments::{BenchScale, Experiment};
pub use service::{
    JobQueue, ModelCache, PartitionRequest, PartitionResponse, Popped, Service, ServiceConfig,
};
pub use transport::{ReconnectPolicy, ServiceClient, TcpServer, TcpServerConfig, WorkerOptions};
