//! L3 coordinator: the partition service, its metrics, and the
//! experiment runners that regenerate the paper's figures.
//!
//! TOAST is a compiler-side system, so the coordinator's job is a
//! partition-request service: clients submit `(model, mesh, hardware,
//! method, budget)` requests; a worker pool runs the analysis + search and
//! returns sharding specs with cost reports. The CLI (`toast serve`,
//! `toast partition`, `toast bench`) fronts this service.

pub mod experiments;
pub mod metrics;
pub mod service;

pub use experiments::{BenchScale, Experiment};
pub use service::{PartitionRequest, PartitionResponse, Service};
