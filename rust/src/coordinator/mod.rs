//! L3 coordinator: the partition service, its metrics, and the
//! experiment runners that regenerate the paper's figures.
//!
//! TOAST is a compiler-side system, so the coordinator's job is a
//! partition-request service: clients submit `(model-source, mesh,
//! hardware, method, budget)` requests — the model is a zoo name *or* a
//! serialized `Func` — a worker pool resolves each to a shared
//! [`crate::api::CompiledModel`] (analysis runs once per model, not per
//! request), runs the strategy, and returns a serializable
//! [`crate::api::Solution`]. Accepted specs are replayed through the
//! differential harness before the service trusts them
//! (trust-but-verify; see [`service`]). The CLI (`toast serve`,
//! `toast partition`, `toast bench`) fronts this service.

pub mod experiments;
pub mod metrics;
pub mod service;

pub use experiments::{BenchScale, Experiment};
pub use service::{PartitionRequest, PartitionResponse, Service, ServiceConfig};
