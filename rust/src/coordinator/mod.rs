//! L3 coordinator: the partition service, its two transports, its
//! metrics, and the experiment runners that regenerate the paper's
//! figures.
//!
//! TOAST is a compiler-side system, so the coordinator's job is a
//! partition-request service: clients submit `(model-source, mesh,
//! hardware, method, budget)` requests — the model is a zoo name *or* a
//! serialized `Func` — a worker pool resolves each to a shared
//! [`crate::api::CompiledModel`] (analysis runs once per model, not per
//! request), runs the strategy, and returns a serializable
//! [`crate::api::PartitionResponse`]. Accepted specs are replayed
//! through the differential harness before the service trusts them
//! (trust-but-verify; see [`service`]).
//!
//! Two transports, one dispatch/verify path: the default in-process
//! thread pool ([`Service`]) and the socket mode ([`transport`]) — a
//! length-prefixed JSON wire protocol over TCP behind `toast serve
//! --listen`, with workers as OS processes (`toast worker --connect`)
//! and a submit/status client (`toast submit --connect`). Both pull the
//! same [`service::JobQueue`], both run [`service::process_request`],
//! and both terminate every response through
//! [`service::ServiceShared`]'s shared completion path.
//!
//! ## The cache-first request path
//!
//! Every submission — thread mode or socket mode — runs the same
//! admission sequence:
//!
//! 1. **Solution cache** ([`service::SolutionCache`]): repeated requests
//!    (same model fingerprint, mesh, topology fingerprint, method,
//!    budget, seed) are
//!    answered with the cached, already-verified artifact in
//!    microseconds, with zero dispatches. LRU-bounded; `--no-cache`
//!    bypasses it per request. Because deterministic (single-threaded,
//!    fixed-seed) searches reproduce bit-identically, a hit returns
//!    byte-for-byte what a fresh search would.
//! 2. **Admission control**: with a queue-depth bound configured, a
//!    full queue refuses the submit with a structured
//!    [`service::Overloaded`] error (an `overloaded` frame on the wire)
//!    instead of queueing unbounded work.
//! 3. **Queue + dispatch**: misses flow to the [`service::JobQueue`];
//!    socket workers pipeline up to [`TcpServerConfig::capacity`] jobs
//!    per connection, with per-job exactly-once requeue if the worker
//!    dies.
//!
//! ## Trust model
//!
//! In-process workers are trusted (same address space). Socket workers
//! run their *own* trust-but-verify replay, so a Byzantine worker could
//! forge the validation record on a result; the server re-verifies a
//! sampled fraction ([`TcpServerConfig::audit_fraction`]) of
//! worker-claimed records by replaying them through the differential
//! harness itself, rejecting — and never caching — any result whose
//! claim does not reproduce. Auth and TLS for the listening port remain
//! open follow-ons (ROADMAP); until then the port should stay on
//! localhost or a trusted network.
//!
//! ## Observability
//!
//! Three zero-dependency layers, all rooted in [`crate::obs`]:
//!
//! 1. **Structured tracing**: the search hot path (select/expand,
//!    batched leaf flush, backprop, incremental replay), the
//!    partitioner, and the full request lifecycle (admit → queue wait →
//!    dispatch → search → verify/audit → respond) carry
//!    [`crate::obs::span`]/[`crate::obs::event`] probes. Disabled by
//!    default at near-zero cost (one relaxed atomic load); when enabled
//!    ([`crate::obs::set_enabled`]) events land in a bounded
//!    lock-striped ring that drops oldest and never blocks.
//!    `toast trace --out trace.json` drains the ring as Chrome
//!    trace-event JSON (Perfetto / `chrome://tracing`).
//! 2. **Per-search telemetry**: sessions run with
//!    [`crate::api::Partitioner::trace`] attach a
//!    [`crate::obs::SearchTrace`] (best-cost-over-evals curve, tree
//!    size, transposition merges, eval-cache hit rates, per-phase time)
//!    to the [`crate::api::Solution`]; the wire field is omitted when
//!    absent so untraced artifacts are byte-identical to pre-tracing
//!    ones. Tracing observes, never steers: solutions are byte-identical
//!    with it on or off.
//! 3. **Live latency histograms**: lock-free log-bucketed
//!    [`crate::obs::Histogram`]s in [`metrics::Metrics`] record
//!    queue-wait, cold-search, cache-hit and verify latency per
//!    request. Digests (true p50/p99 within one log bucket) flow into
//!    every status report (`workers_detail` rides along), and a
//!    `metrics` wire request answers the Prometheus text exposition —
//!    `toast status --prom` serves it verbatim to a scrape job.
//!
//! Opening a trace: `toast trace --model attention --mesh 2x2 --out
//! trace.json`, then load the file at <https://ui.perfetto.dev> (or
//! `chrome://tracing`). Scraping: the exposition has no HTTP endpoint
//! (the wire protocol is framed JSON), so point a textfile collector at
//! it — e.g. a cron'd `toast status --connect HOST:PORT --prom >
//! /var/lib/node_exporter/toast.prom` picked up by node_exporter's
//! textfile module, or any sidecar that shells out per scrape.

pub mod experiments;
pub mod metrics;
pub mod service;
pub mod transport;

pub use experiments::{BenchScale, Experiment};
pub use service::{
    JobQueue, ModelCache, Overloaded, PartitionRequest, PartitionResponse, Popped, Service,
    ServiceConfig, SolutionCache,
};
pub use transport::{ReconnectPolicy, ServiceClient, TcpServer, TcpServerConfig, WorkerOptions};
