//! L3 coordinator: the partition service, its two transports, its
//! metrics, and the experiment runners that regenerate the paper's
//! figures.
//!
//! TOAST is a compiler-side system, so the coordinator's job is a
//! partition-request service: clients submit `(model-source, mesh,
//! hardware, method, budget)` requests — the model is a zoo name *or* a
//! serialized `Func` — a worker pool resolves each to a shared
//! [`crate::api::CompiledModel`] (analysis runs once per model, not per
//! request), runs the strategy, and returns a serializable
//! [`crate::api::PartitionResponse`]. Accepted specs are replayed
//! through the differential harness before the service trusts them
//! (trust-but-verify; see [`service`]).
//!
//! Two transports, one dispatch/verify path: the default in-process
//! thread pool ([`Service`]) and the socket mode ([`transport`]) — a
//! length-prefixed JSON wire protocol over TCP behind `toast serve
//! --listen`, with workers as OS processes (`toast worker --connect`)
//! and a submit/status client (`toast submit --connect`). Both pull the
//! same [`service::JobQueue`], both run [`service::process_request`],
//! and both terminate every response through
//! [`service::ServiceShared`]'s shared completion path.
//!
//! ## The cache-first request path
//!
//! Every submission — thread mode or socket mode — runs the same
//! admission sequence:
//!
//! 1. **Solution cache** ([`service::SolutionCache`]): repeated requests
//!    (same model fingerprint, mesh, topology fingerprint, method,
//!    budget, seed) are
//!    answered with the cached, already-verified artifact in
//!    microseconds, with zero dispatches. LRU-bounded; `--no-cache`
//!    bypasses it per request. Because deterministic (single-threaded,
//!    fixed-seed) searches reproduce bit-identically, a hit returns
//!    byte-for-byte what a fresh search would.
//! 2. **Admission control**: with a queue-depth bound configured, a
//!    full queue refuses the submit with a structured
//!    [`service::Overloaded`] error (an `overloaded` frame on the wire)
//!    instead of queueing unbounded work.
//! 3. **Queue + dispatch**: misses flow to the [`service::JobQueue`];
//!    socket workers pipeline up to [`TcpServerConfig::capacity`] jobs
//!    per connection, with per-job exactly-once requeue if the worker
//!    dies.
//!
//! ## Trust model
//!
//! In-process workers are trusted (same address space). Socket workers
//! run their *own* trust-but-verify replay, so a Byzantine worker could
//! forge the validation record on a result; the server re-verifies a
//! sampled fraction ([`TcpServerConfig::audit_fraction`]) of
//! worker-claimed records by replaying them through the differential
//! harness itself, rejecting — and never caching — any result whose
//! claim does not reproduce. Auth and TLS for the listening port remain
//! open follow-ons (ROADMAP); until then the port should stay on
//! localhost or a trusted network.

pub mod experiments;
pub mod metrics;
pub mod service;
pub mod transport;

pub use experiments::{BenchScale, Experiment};
pub use service::{
    JobQueue, ModelCache, Overloaded, PartitionRequest, PartitionResponse, Popped, Service,
    ServiceConfig, SolutionCache,
};
pub use transport::{ReconnectPolicy, ServiceClient, TcpServer, TcpServerConfig, WorkerOptions};
