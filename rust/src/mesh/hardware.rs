//! Hardware topology model: per-device-class compute/memory plus
//! per-mesh-axis interconnect tiers.
//!
//! A [`Topology`] is the first-class, serializable description of the
//! machine the cost model prices against. It pairs one [`DeviceClass`]
//! (peak FLOPs, HBM bandwidth, memory capacity, matmul efficiency) with
//! one [`LinkTier`] per mesh axis: `tiers[i]` is the fabric collectives
//! on mesh axis `i` traverse. Tiers are ordered inner (fastest) to
//! outer (slowest) by convention — NVLink/ICI islands first, IB/DCN
//! spines behind them — so hierarchical machines are described directly
//! and the search can place pipeline stages on the slow axis while
//! sharding rides the fast one.
//!
//! Built-in profiles (resolve via [`Topology::named`]):
//!
//! | name               | device | tiers (bandwidth, latency)                   |
//! |--------------------|--------|----------------------------------------------|
//! | `a100`             | A100   | (300 GB/s, 2 µs) (100 GB/s, 2 µs) (25 GB/s, 2 µs) |
//! | `p100`             | P100   | (80 GB/s, 5 µs) (32 GB/s, 5 µs) (12 GB/s, 5 µs)   |
//! | `tpuv3`            | TPUv3  | (140 GB/s, 1 µs) (140 GB/s, 1 µs) (70 GB/s, 1 µs) |
//! | `a100-flat-8`      | A100   | (300 GB/s, 2 µs) × 3 — idealized flat NVLink fabric |
//! | `a100-2x4-islands` | A100   | (300 GB/s, 2 µs) (25 GB/s, 5 µs) (25 GB/s, 5 µs) — NVLink islands of 4, IB spine |
//!
//! Numbers are public peak specs; the cost model only relies on
//! *relative* magnitudes (§4.5 uses relative runtime), so modest
//! inaccuracies do not change method rankings. Custom machines load
//! from JSON ([`Topology::from_json`]) with exact `f64` round-trips.
//!
//! A mesh axis beyond the described tiers is a hard error in
//! [`Topology::axis_tier`] (the mesh must fit the machine); the one
//! deliberate exception is the pipeline *stage* axis, which
//! [`Topology::stage_tier`] maps to the outermost tier when the intra
//! mesh already consumes every described tier — inter-stage traffic
//! crosses at least the slowest fabric.

use crate::mesh::Mesh;
use crate::util::json::Json;
use anyhow::{anyhow, ensure};

/// The platform enum of the paper's evaluation (§5.1). Kept as the
/// legacy spelling of the three classic profiles; new code should name
/// topologies directly ([`Topology::named`] / [`Topology::from_kind`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum HardwareKind {
    A100,
    P100,
    TPUv3,
}

impl HardwareKind {
    pub fn name(self) -> &'static str {
        match self {
            HardwareKind::A100 => "A100",
            HardwareKind::P100 => "P100",
            HardwareKind::TPUv3 => "TPUv3",
        }
    }

    pub fn all() -> [HardwareKind; 3] {
        [HardwareKind::A100, HardwareKind::P100, HardwareKind::TPUv3]
    }
}

impl std::str::FromStr for HardwareKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "a100" => Ok(HardwareKind::A100),
            "p100" => Ok(HardwareKind::P100),
            "tpuv3" | "tpu" => Ok(HardwareKind::TPUv3),
            other => Err(format!("unknown hardware '{other}' (a100|p100|tpuv3)")),
        }
    }
}

/// One interconnect tier: the link collectives on a mesh axis traverse.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkTier {
    /// Per-link bandwidth in one direction, bytes/s.
    pub bandwidth: f64,
    /// Per-hop collective latency, seconds.
    pub latency: f64,
}

impl LinkTier {
    pub fn new(bandwidth: f64, latency: f64) -> LinkTier {
        LinkTier { bandwidth, latency }
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("bandwidth", Json::n(self.bandwidth)),
            ("latency", Json::n(self.latency)),
        ])
    }

    fn from_json(j: &Json) -> crate::Result<LinkTier> {
        let tier = LinkTier {
            bandwidth: f64_field(j, "bandwidth", "link tier")?,
            latency: f64_field(j, "latency", "link tier")?,
        };
        ensure!(tier.bandwidth > 0.0, "link tier: bandwidth must be > 0");
        ensure!(tier.latency >= 0.0, "link tier: latency must be >= 0");
        Ok(tier)
    }
}

/// Per-device compute and memory characteristics (one class per
/// topology; mixed generations within a mesh are a planned extension).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceClass {
    /// Peak dense matmul throughput at the model dtype, FLOP/s.
    pub flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bandwidth: f64,
    /// Per-device memory capacity, bytes.
    pub memory_bytes: u64,
    /// Achievable fraction of peak FLOPs for large matmuls.
    pub matmul_efficiency: f64,
}

impl DeviceClass {
    /// Effective matmul FLOP/s after efficiency derating.
    pub fn effective_flops(&self) -> f64 {
        self.flops * self.matmul_efficiency
    }

    /// A100 SXM: 312 TFLOP/s bf16, 2.0 TB/s HBM2e, 80 GB.
    pub fn a100() -> DeviceClass {
        DeviceClass {
            flops: 312e12,
            hbm_bandwidth: 2.0e12,
            memory_bytes: 80 * (1 << 30),
            matmul_efficiency: 0.55,
        }
    }

    /// P100: 21.2 TFLOP/s fp16, 732 GB/s HBM2, 16 GB.
    pub fn p100() -> DeviceClass {
        DeviceClass {
            flops: 21.2e12,
            hbm_bandwidth: 732e9,
            memory_bytes: 16 * (1 << 30),
            matmul_efficiency: 0.50,
        }
    }

    /// TPUv3: 123 TFLOP/s bf16 per chip, 900 GB/s HBM, 32 GB (16 per
    /// core x2).
    pub fn tpuv3() -> DeviceClass {
        DeviceClass {
            flops: 123e12,
            hbm_bandwidth: 900e9,
            memory_bytes: 32 * (1 << 30),
            matmul_efficiency: 0.65,
        }
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("flops", Json::n(self.flops)),
            ("hbm_bandwidth", Json::n(self.hbm_bandwidth)),
            ("memory_bytes", u64_to_json(self.memory_bytes)),
            ("matmul_efficiency", Json::n(self.matmul_efficiency)),
        ])
    }

    fn from_json(j: &Json) -> crate::Result<DeviceClass> {
        let ctx = "device class";
        let dc = DeviceClass {
            flops: f64_field(j, "flops", ctx)?,
            hbm_bandwidth: f64_field(j, "hbm_bandwidth", ctx)?,
            memory_bytes: u64_field(j, "memory_bytes", ctx)?,
            matmul_efficiency: f64_field(j, "matmul_efficiency", ctx)?,
        };
        ensure!(dc.flops > 0.0, "{ctx}: flops must be > 0");
        ensure!(dc.hbm_bandwidth > 0.0, "{ctx}: hbm_bandwidth must be > 0");
        ensure!(
            dc.matmul_efficiency > 0.0 && dc.matmul_efficiency <= 1.0,
            "{ctx}: matmul_efficiency must be in (0, 1]"
        );
        Ok(dc)
    }
}

/// A machine description: one device class plus one link tier per mesh
/// axis, inner (fastest) to outer (slowest). See the module docs for
/// the built-in profiles and the JSON wire form.
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    /// Profile name; presets use their [`Topology::named`] spelling,
    /// custom files carry whatever their author wrote.
    pub name: String,
    pub device: DeviceClass,
    /// `tiers[i]` prices collectives on mesh axis `i`. Must cover every
    /// mesh axis ([`Topology::check_mesh`]); may describe more tiers
    /// than the mesh uses (e.g. one for an appended pipeline stage
    /// axis).
    pub tiers: Vec<LinkTier>,
}

impl Topology {
    pub fn new(name: impl Into<String>, device: DeviceClass, tiers: Vec<LinkTier>) -> Topology {
        assert!(!tiers.is_empty(), "topology needs at least one link tier");
        Topology { name: name.into(), device, tiers }
    }

    /// The preset a [`HardwareKind`] maps to — the legacy enum's pricing
    /// is preserved exactly (same bandwidths, one shared latency across
    /// tiers).
    pub fn from_kind(kind: HardwareKind) -> Topology {
        match kind {
            HardwareKind::A100 => Topology::new(
                "a100",
                DeviceClass::a100(),
                vec![
                    LinkTier::new(300e9, 2e-6),
                    LinkTier::new(100e9, 2e-6),
                    LinkTier::new(25e9, 2e-6),
                ],
            ),
            HardwareKind::P100 => Topology::new(
                "p100",
                DeviceClass::p100(),
                vec![
                    LinkTier::new(80e9, 5e-6),
                    LinkTier::new(32e9, 5e-6),
                    LinkTier::new(12e9, 5e-6),
                ],
            ),
            HardwareKind::TPUv3 => Topology::new(
                "tpuv3",
                DeviceClass::tpuv3(),
                vec![
                    LinkTier::new(140e9, 1e-6),
                    LinkTier::new(140e9, 1e-6),
                    LinkTier::new(70e9, 1e-6),
                ],
            ),
        }
    }

    /// Resolve a named preset (see the module-doc table).
    pub fn named(name: &str) -> crate::Result<Topology> {
        match name.to_ascii_lowercase().as_str() {
            "a100" => Ok(Topology::from_kind(HardwareKind::A100)),
            "p100" => Ok(Topology::from_kind(HardwareKind::P100)),
            "tpuv3" | "tpu" => Ok(Topology::from_kind(HardwareKind::TPUv3)),
            // Idealized fully switched NVLink fabric over 8 GPUs: every
            // axis — including an appended pipeline stage axis — rides
            // the fast tier.
            "a100-flat-8" => Ok(Topology::new(
                "a100-flat-8",
                DeviceClass::a100(),
                vec![LinkTier::new(300e9, 2e-6); 3],
            )),
            // Two NVLink islands of four GPUs: mesh axis 0 stays inside
            // an island (NVLink), axis 1 crosses islands over the IB
            // spine, and a pipeline stage axis rides the spine too.
            "a100-2x4-islands" => Ok(Topology::new(
                "a100-2x4-islands",
                DeviceClass::a100(),
                vec![
                    LinkTier::new(300e9, 2e-6),
                    LinkTier::new(25e9, 5e-6),
                    LinkTier::new(25e9, 5e-6),
                ],
            )),
            other => Err(anyhow!(
                "unknown topology '{other}' (presets: {}; or pass a JSON topology file)",
                Topology::preset_names().join("|")
            )),
        }
    }

    /// Names [`Topology::named`] resolves.
    pub fn preset_names() -> [&'static str; 5] {
        ["a100", "p100", "tpuv3", "a100-flat-8", "a100-2x4-islands"]
    }

    /// The legacy enum this topology is the preset of, if any — used to
    /// emit the backward-compatible `hardware` wire field and by the
    /// Alpa baseline's platform tuning.
    pub fn kind_hint(&self) -> Option<HardwareKind> {
        match self.name.as_str() {
            "a100" => Some(HardwareKind::A100),
            "p100" => Some(HardwareKind::P100),
            "tpuv3" => Some(HardwareKind::TPUv3),
            _ => None,
        }
    }

    /// Effective matmul FLOP/s after efficiency derating.
    pub fn effective_flops(&self) -> f64 {
        self.device.effective_flops()
    }

    /// The link tier of mesh axis `axis`. Hard error (panic) when the
    /// axis is not described: the mesh must fit the machine — use
    /// [`Topology::check_mesh`] at API boundaries to surface this as a
    /// `Result` before pricing starts.
    pub fn axis_tier(&self, axis: usize) -> &LinkTier {
        match self.tiers.get(axis) {
            Some(t) => t,
            None => panic!(
                "mesh axis {axis} has no link tier: topology '{}' describes {} tier(s); \
                 the mesh rank must not exceed the tier count",
                self.name,
                self.tiers.len()
            ),
        }
    }

    /// Link bandwidth of mesh axis `axis` (see [`Topology::axis_tier`]).
    pub fn axis_bandwidth(&self, axis: usize) -> f64 {
        self.axis_tier(axis).bandwidth
    }

    /// Per-hop latency of mesh axis `axis` (see [`Topology::axis_tier`]).
    pub fn axis_latency(&self, axis: usize) -> f64 {
        self.axis_tier(axis).latency
    }

    /// The tier stage-to-stage point-to-point transfers ride. The stage
    /// axis is appended *behind* the intra mesh, so when the intra mesh
    /// already consumes every described tier the stage axis maps to the
    /// outermost (slowest) one — inter-stage traffic crosses at least
    /// the slowest fabric.
    pub fn stage_tier(&self, stage_axis: usize) -> &LinkTier {
        self.tiers
            .get(stage_axis)
            .unwrap_or_else(|| self.tiers.last().expect("topology has at least one tier"))
    }

    /// Does this topology describe every axis of `mesh`? Call at API
    /// boundaries so a mesh/topology mismatch is a friendly error
    /// instead of a panic deep inside pricing.
    pub fn check_mesh(&self, mesh: &Mesh) -> crate::Result<()> {
        ensure!(
            mesh.rank() <= self.tiers.len(),
            "mesh {} has {} axes but topology '{}' describes only {} link tier(s); \
             every mesh axis needs a tier",
            mesh.describe(),
            mesh.rank(),
            self.name,
            self.tiers.len()
        );
        Ok(())
    }

    /// Wire form: `{"name":..,"device":{..},"tiers":[{..},..]}`. Numbers
    /// round-trip exactly (the JSON layer renders `f64` losslessly).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::s(self.name.clone())),
            ("device", self.device.to_json()),
            ("tiers", Json::Arr(self.tiers.iter().map(|t| t.to_json()).collect())),
        ])
    }

    /// Inverse of [`Topology::to_json`].
    pub fn from_json(j: &Json) -> crate::Result<Topology> {
        let ctx = "topology";
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{ctx}: missing field 'name'"))?
            .to_string();
        let device =
            DeviceClass::from_json(j.get("device").ok_or_else(|| {
                anyhow!("{ctx} '{name}': missing field 'device'")
            })?)?;
        let tiers = match j.get("tiers") {
            Some(Json::Arr(items)) => {
                items.iter().map(LinkTier::from_json).collect::<crate::Result<Vec<_>>>()?
            }
            _ => return Err(anyhow!("{ctx} '{name}': missing or non-array field 'tiers'")),
        };
        ensure!(!tiers.is_empty(), "{ctx} '{name}': needs at least one link tier");
        Ok(Topology { name, device, tiers })
    }

    /// Render as a JSON document (the `--topology file.json` format).
    pub fn to_json_string(&self) -> String {
        self.to_json().render()
    }

    /// Parse a JSON document produced by [`Topology::to_json_string`].
    pub fn from_json_str(s: &str) -> crate::Result<Topology> {
        Topology::from_json(&Json::parse(s)?)
    }

    /// Stable fingerprint for solution-cache keying: FNV-1a over the
    /// rendered wire form, so two requests hash equal exactly when their
    /// serialized topologies are identical.
    pub fn fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let rendered = self.to_json().render();
        let mut hash = FNV_OFFSET;
        for byte in rendered.as_bytes() {
            hash ^= u64::from(*byte);
            hash = hash.wrapping_mul(FNV_PRIME);
        }
        hash
    }
}

// ---- local wire helpers (the mesh layer cannot depend on api::wire) -----

fn f64_field(j: &Json, key: &str, ctx: &str) -> crate::Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("{ctx}: field '{key}' missing or not a number"))
}

/// Exact u64 on the wire: plain number when representable in f64,
/// decimal string beyond 2^53.
fn u64_to_json(v: u64) -> Json {
    if v <= (1u64 << 53) {
        Json::n(v as f64)
    } else {
        Json::s(v.to_string())
    }
}

fn u64_field(j: &Json, key: &str, ctx: &str) -> crate::Result<u64> {
    let v = j.get(key).ok_or_else(|| anyhow!("{ctx}: missing field '{key}'"))?;
    if let Some(s) = v.as_str() {
        return s.parse().map_err(|_| anyhow!("{ctx}: field '{key}' is not a u64"));
    }
    v.as_f64()
        .filter(|f| *f >= 0.0 && f.fract() == 0.0)
        .map(|f| f as u64)
        .ok_or_else(|| anyhow!("{ctx}: field '{key}' is not a u64"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_sane() {
        for name in Topology::preset_names() {
            let t = Topology::named(name).unwrap();
            assert_eq!(t.name, name);
            assert!(t.device.flops > 1e12);
            assert!(t.device.hbm_bandwidth > 1e11);
            assert!(t.device.memory_bytes >= 16 * (1 << 30));
            assert!(!t.tiers.is_empty());
            assert!(t.device.matmul_efficiency > 0.0 && t.device.matmul_efficiency <= 1.0);
            for tier in &t.tiers {
                assert!(tier.bandwidth > 0.0 && tier.latency >= 0.0);
            }
        }
        assert!(Topology::named("h100").is_err());
    }

    #[test]
    fn a100_faster_than_p100() {
        let a = Topology::from_kind(HardwareKind::A100);
        let p = Topology::from_kind(HardwareKind::P100);
        assert!(a.effective_flops() > p.effective_flops());
        assert!(a.axis_bandwidth(0) > p.axis_bandwidth(0));
    }

    #[test]
    fn kind_presets_keep_legacy_numbers() {
        // The deprecated enum path must price exactly as it always did.
        let a = Topology::from_kind(HardwareKind::A100);
        assert_eq!(
            a.tiers.iter().map(|t| t.bandwidth).collect::<Vec<_>>(),
            vec![300e9, 100e9, 25e9]
        );
        assert!(a.tiers.iter().all(|t| t.latency == 2e-6));
        assert_eq!(a.kind_hint(), Some(HardwareKind::A100));
        assert_eq!(Topology::named("a100-2x4-islands").unwrap().kind_hint(), None);
    }

    #[test]
    #[should_panic(expected = "has no link tier")]
    fn axis_beyond_tiers_is_a_hard_error() {
        // The pre-topology model silently clamped axis 7 to the last
        // bandwidth entry; explicit tiers make that a hard error.
        let a = Topology::from_kind(HardwareKind::A100);
        let _ = a.axis_bandwidth(7);
    }

    #[test]
    fn check_mesh_rejects_undescribed_axes() {
        // Regression: a 3-axis mesh over a 2-tier profile must fail
        // loudly, not clamp to the last tier.
        let two_tier = Topology::new(
            "island-pair",
            DeviceClass::a100(),
            vec![LinkTier::new(300e9, 2e-6), LinkTier::new(25e9, 5e-6)],
        );
        let three = Mesh::grid(&[("a", 2), ("b", 2), ("c", 2)]);
        let err = two_tier.check_mesh(&three).unwrap_err().to_string();
        assert!(err.contains("3 axes") && err.contains("2 link tier(s)"), "{err}");
        assert!(two_tier.check_mesh(&Mesh::grid(&[("a", 2), ("b", 2)])).is_ok());
    }

    #[test]
    fn stage_tier_clamps_to_outermost() {
        let t = Topology::named("a100-2x4-islands").unwrap();
        // Within the described tiers: exact.
        assert_eq!(t.stage_tier(1).bandwidth, 25e9);
        // Beyond them (intra mesh consumed all tiers): outermost.
        assert_eq!(t.stage_tier(5).bandwidth, t.tiers.last().unwrap().bandwidth);
    }

    #[test]
    fn topology_json_roundtrips_exactly() {
        let custom = Topology::new(
            "weird-lab-rig",
            DeviceClass {
                flops: 197.3e12,
                hbm_bandwidth: 1.63e12,
                memory_bytes: (1u64 << 53) + 7, // exercises the string path
                matmul_efficiency: 0.47,
            },
            vec![LinkTier::new(123.456e9, 1.7e-6), LinkTier::new(9.87e9, 11.1e-6)],
        );
        let back = Topology::from_json_str(&custom.to_json_string()).unwrap();
        assert_eq!(back.name, custom.name);
        assert_eq!(back.device.memory_bytes, custom.device.memory_bytes);
        assert_eq!(back.device.flops.to_bits(), custom.device.flops.to_bits());
        assert_eq!(
            back.device.matmul_efficiency.to_bits(),
            custom.device.matmul_efficiency.to_bits()
        );
        for (a, b) in back.tiers.iter().zip(&custom.tiers) {
            assert_eq!(a.bandwidth.to_bits(), b.bandwidth.to_bits());
            assert_eq!(a.latency.to_bits(), b.latency.to_bits());
        }
        assert_eq!(back, custom);
        assert_eq!(back.fingerprint(), custom.fingerprint());
    }

    #[test]
    fn fingerprint_separates_profiles() {
        let names = Topology::preset_names();
        let fps: Vec<u64> =
            names.iter().map(|n| Topology::named(n).unwrap().fingerprint()).collect();
        for i in 0..fps.len() {
            for j in (i + 1)..fps.len() {
                assert_ne!(fps[i], fps[j], "{} vs {}", names[i], names[j]);
            }
        }
    }

    #[test]
    fn from_json_validates() {
        assert!(Topology::from_json_str(r#"{"name":"x"}"#).is_err());
        let no_tiers = r#"{"name":"x","device":{"flops":1e12,"hbm_bandwidth":1e12,
            "memory_bytes":1000000,"matmul_efficiency":0.5},"tiers":[]}"#;
        assert!(Topology::from_json_str(no_tiers).is_err());
        let bad_bw = r#"{"name":"x","device":{"flops":1e12,"hbm_bandwidth":1e12,
            "memory_bytes":1000000,"matmul_efficiency":0.5},
            "tiers":[{"bandwidth":0.0,"latency":1e-6}]}"#;
        assert!(Topology::from_json_str(bad_bw).is_err());
    }

    #[test]
    fn parse_hardware_kind() {
        assert_eq!("a100".parse::<HardwareKind>().unwrap(), HardwareKind::A100);
        assert_eq!("TPUv3".parse::<HardwareKind>().unwrap(), HardwareKind::TPUv3);
        assert!("h100".parse::<HardwareKind>().is_err());
    }
}
