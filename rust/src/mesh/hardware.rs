//! Hardware profiles for the platforms in the paper's evaluation (§5.1):
//! NVIDIA A100 (NVLink), NVIDIA P100 (PCIe-era NVLink), and Google TPUv3
//! (ICI). Numbers are public peak specs; the cost model only relies on
//! *relative* magnitudes (§4.5 uses relative runtime), so modest
//! inaccuracies do not change method rankings.



/// Supported accelerator platforms.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum HardwareKind {
    A100,
    P100,
    TPUv3,
}

impl HardwareKind {
    pub fn name(self) -> &'static str {
        match self {
            HardwareKind::A100 => "A100",
            HardwareKind::P100 => "P100",
            HardwareKind::TPUv3 => "TPUv3",
        }
    }

    pub fn all() -> [HardwareKind; 3] {
        [HardwareKind::A100, HardwareKind::P100, HardwareKind::TPUv3]
    }
}

impl std::str::FromStr for HardwareKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "a100" => Ok(HardwareKind::A100),
            "p100" => Ok(HardwareKind::P100),
            "tpuv3" | "tpu" => Ok(HardwareKind::TPUv3),
            other => Err(format!("unknown hardware '{other}' (a100|p100|tpuv3)")),
        }
    }
}

/// Per-device characteristics plus interconnect parameters.
#[derive(Clone, Debug)]
pub struct HardwareProfile {
    pub kind: HardwareKind,
    /// Peak dense matmul throughput at the model dtype, FLOP/s.
    pub flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bandwidth: f64,
    /// Per-device memory capacity, bytes.
    pub memory_bytes: u64,
    /// Interconnect (all-reduce ring) bandwidth per link, bytes/s.
    /// `link_bandwidth[i]` applies to mesh axis `i`; axes beyond the list
    /// reuse the last entry (e.g. DCN-ish outer axes are slower).
    pub link_bandwidth: Vec<f64>,
    /// Per-hop collective latency, seconds.
    pub link_latency: f64,
    /// Achievable fraction of peak FLOPs for large matmuls.
    pub matmul_efficiency: f64,
}

impl HardwareProfile {
    /// Public peak numbers; `link_bandwidth[0]` is the fast inner axis
    /// (NVLink / ICI), later entries model slower outer axes.
    pub fn new(kind: HardwareKind) -> Self {
        match kind {
            // A100 SXM: 312 TFLOP/s bf16, 2.0 TB/s HBM2e, 80 GB,
            // NVLink3 600 GB/s total (~300 GB/s per direction).
            HardwareKind::A100 => HardwareProfile {
                kind,
                flops: 312e12,
                hbm_bandwidth: 2.0e12,
                memory_bytes: 80 * (1 << 30),
                link_bandwidth: vec![300e9, 100e9, 25e9],
                link_latency: 2e-6,
                matmul_efficiency: 0.55,
            },
            // P100: 21.2 TFLOP/s fp16, 732 GB/s HBM2, 16 GB, NVLink1
            // 160 GB/s total (~80 GB/s per direction).
            HardwareKind::P100 => HardwareProfile {
                kind,
                flops: 21.2e12,
                hbm_bandwidth: 732e9,
                memory_bytes: 16 * (1 << 30),
                link_bandwidth: vec![80e9, 32e9, 12e9],
                link_latency: 5e-6,
                matmul_efficiency: 0.50,
            },
            // TPUv3: 123 TFLOP/s bf16 per chip, 900 GB/s HBM, 32 GB (16
            // per core x2), ICI ~70 GB/s per link x multiple links.
            HardwareKind::TPUv3 => HardwareProfile {
                kind,
                flops: 123e12,
                hbm_bandwidth: 900e9,
                memory_bytes: 32 * (1 << 30),
                link_bandwidth: vec![140e9, 140e9, 70e9],
                link_latency: 1e-6,
                matmul_efficiency: 0.65,
            },
        }
    }

    /// Link bandwidth for mesh axis `axis`.
    pub fn axis_bandwidth(&self, axis: usize) -> f64 {
        *self
            .link_bandwidth
            .get(axis)
            .unwrap_or_else(|| self.link_bandwidth.last().expect("non-empty link_bandwidth"))
    }

    /// Effective matmul FLOP/s after efficiency derating.
    pub fn effective_flops(&self) -> f64 {
        self.flops * self.matmul_efficiency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_are_sane() {
        for kind in HardwareKind::all() {
            let p = HardwareProfile::new(kind);
            assert!(p.flops > 1e12);
            assert!(p.hbm_bandwidth > 1e11);
            assert!(p.memory_bytes >= 16 * (1 << 30));
            assert!(!p.link_bandwidth.is_empty());
            assert!(p.matmul_efficiency > 0.0 && p.matmul_efficiency <= 1.0);
        }
    }

    #[test]
    fn a100_faster_than_p100() {
        let a = HardwareProfile::new(HardwareKind::A100);
        let p = HardwareProfile::new(HardwareKind::P100);
        assert!(a.effective_flops() > p.effective_flops());
        assert!(a.axis_bandwidth(0) > p.axis_bandwidth(0));
    }

    #[test]
    fn axis_bandwidth_clamps_to_last() {
        let a = HardwareProfile::new(HardwareKind::A100);
        assert_eq!(a.axis_bandwidth(7), *a.link_bandwidth.last().unwrap());
    }

    #[test]
    fn parse_hardware_kind() {
        assert_eq!("a100".parse::<HardwareKind>().unwrap(), HardwareKind::A100);
        assert_eq!("TPUv3".parse::<HardwareKind>().unwrap(), HardwareKind::TPUv3);
        assert!("h100".parse::<HardwareKind>().is_err());
    }
}
