//! Logical device meshes and hardware topologies (§2.1, §5.1).
//!
//! A mesh is an n-dimensional lattice of devices spanned by named axes
//! (e.g. `2x32x2` over `batch × seq × model`). Devices are numbered
//! row-major over the axis coordinates. The [`Topology`] attaches
//! per-device-class compute/memory characteristics and one interconnect
//! [`LinkTier`] per mesh axis (NVLink-island inner axes vs IB/DCN outer
//! axes), which drive the cost model ([`crate::cost`]).

pub mod hardware;

pub use hardware::{DeviceClass, HardwareKind, LinkTier, Topology};

use crate::ir::AxisId;
use crate::util::json::Json;


/// A named mesh axis.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MeshAxis {
    pub name: String,
    pub size: usize,
}

/// An n-dimensional logical device mesh.
#[derive(Clone, Debug, PartialEq)]
pub struct Mesh {
    pub axes: Vec<MeshAxis>,
}

impl Mesh {
    /// Build a mesh from `(name, size)` pairs.
    pub fn grid(axes: &[(&str, usize)]) -> Self {
        assert!(!axes.is_empty(), "mesh needs at least one axis");
        Mesh {
            axes: axes
                .iter()
                .map(|(n, s)| {
                    assert!(*s >= 1, "axis size must be >= 1");
                    MeshAxis { name: n.to_string(), size: *s }
                })
                .collect(),
        }
    }

    pub fn rank(&self) -> usize {
        self.axes.len()
    }

    pub fn num_devices(&self) -> usize {
        self.axes.iter().map(|a| a.size).product()
    }

    pub fn axis_size(&self, axis: AxisId) -> usize {
        self.axes[axis].size
    }

    pub fn axis_name(&self, axis: AxisId) -> &str {
        &self.axes[axis].name
    }

    /// Find an axis by name.
    pub fn axis_by_name(&self, name: &str) -> Option<AxisId> {
        self.axes.iter().position(|a| a.name == name)
    }

    /// Row-major strides over axis coordinates.
    fn strides(&self) -> Vec<usize> {
        let mut st = vec![1usize; self.rank()];
        for d in (0..self.rank().saturating_sub(1)).rev() {
            st[d] = st[d + 1] * self.axes[d + 1].size;
        }
        st
    }

    /// Coordinates of a device id.
    pub fn coords(&self, device: usize) -> Vec<usize> {
        let st = self.strides();
        let mut c = Vec::with_capacity(self.rank());
        let mut rem = device;
        for d in 0..self.rank() {
            c.push(rem / st[d]);
            rem %= st[d];
        }
        c
    }

    /// Device id of coordinates.
    pub fn device_at(&self, coords: &[usize]) -> usize {
        let st = self.strides();
        coords.iter().zip(&st).map(|(c, s)| c * s).sum()
    }

    /// Communication groups along one axis: each group contains the
    /// devices that differ only in their `axis` coordinate, ordered by
    /// that coordinate.
    pub fn groups(&self, axis: AxisId) -> Vec<Vec<usize>> {
        let n = self.num_devices();
        let sz = self.axis_size(axis);
        let mut groups: std::collections::BTreeMap<Vec<usize>, Vec<(usize, usize)>> =
            std::collections::BTreeMap::new();
        for d in 0..n {
            let c = self.coords(d);
            let mut key = c.clone();
            let coord = key.remove(axis);
            groups.entry(key).or_default().push((coord, d));
        }
        groups
            .into_values()
            .map(|mut v| {
                v.sort_unstable();
                debug_assert_eq!(v.len(), sz);
                v.into_iter().map(|(_, d)| d).collect()
            })
            .collect()
    }

    /// Communication groups across several axes jointly (for `all_reduce`
    /// over multiple axes): devices that differ only in coordinates of
    /// the given axes.
    pub fn groups_multi(&self, axes: &[AxisId]) -> Vec<Vec<usize>> {
        let n = self.num_devices();
        let mut groups: std::collections::BTreeMap<Vec<usize>, Vec<usize>> =
            std::collections::BTreeMap::new();
        for d in 0..n {
            let c = self.coords(d);
            let key: Vec<usize> = (0..self.rank())
                .filter(|dd| !axes.contains(dd))
                .map(|dd| c[dd])
                .collect();
            groups.entry(key).or_default().push(d);
        }
        groups.into_values().collect()
    }

    /// This mesh with one more axis appended *behind* the existing ones:
    /// every existing axis keeps its [`AxisId`], so sharding specs built
    /// for `self` apply unchanged to the extended mesh. Used by the
    /// pipeline subsystem to add the stage axis
    /// ([`crate::pipeline::staged_mesh`]).
    pub fn with_axis(&self, name: &str, size: usize) -> Mesh {
        assert!(size >= 1, "axis size must be >= 1");
        assert!(
            self.axis_by_name(name).is_none(),
            "mesh already has an axis named '{name}'"
        );
        let mut axes = self.axes.clone();
        axes.push(MeshAxis { name: name.to_string(), size });
        Mesh { axes }
    }

    /// Human-readable description, e.g. `b=2 x m=8 (16 devices)`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> =
            self.axes.iter().map(|a| format!("{}={}", a.name, a.size)).collect();
        format!("{} ({} devices)", parts.join(" x "), self.num_devices())
    }

    /// Wire format: `{"axes":[{"name":"data","size":4},...]}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "axes",
            Json::Arr(
                self.axes
                    .iter()
                    .map(|a| {
                        Json::obj(vec![
                            ("name", Json::s(a.name.clone())),
                            ("size", Json::n(a.size as f64)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }

    /// Inverse of [`Mesh::to_json`]; round-trips exactly.
    pub fn from_json(j: &Json) -> crate::Result<Mesh> {
        let axes = j
            .get("axes")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("mesh: missing 'axes' array"))?;
        anyhow::ensure!(!axes.is_empty(), "mesh: needs at least one axis");
        let axes = axes
            .iter()
            .map(|a| {
                let name = a
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("mesh axis: 'name' missing or not a string"))?;
                let size = a
                    .get("size")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| {
                        anyhow::anyhow!("mesh axis: 'size' missing or not a non-negative integer")
                    })?;
                anyhow::ensure!(size >= 1, "mesh axis '{name}': size must be >= 1");
                Ok(MeshAxis { name: name.to_string(), size })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(Mesh { axes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_roundtrip() {
        let m = Mesh::grid(&[("a", 2), ("b", 3), ("c", 4)]);
        assert_eq!(m.num_devices(), 24);
        for d in 0..24 {
            assert_eq!(m.device_at(&m.coords(d)), d);
        }
    }

    #[test]
    fn groups_cover_all_devices_once() {
        let m = Mesh::grid(&[("a", 2), ("b", 4)]);
        let groups = m.groups(1);
        assert_eq!(groups.len(), 2);
        let mut seen: Vec<usize> = groups.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..8).collect::<Vec<_>>());
        for g in &groups {
            assert_eq!(g.len(), 4);
            // all share the axis-0 coordinate
            let c0 = m.coords(g[0])[0];
            assert!(g.iter().all(|&d| m.coords(d)[0] == c0));
        }
    }

    #[test]
    fn groups_multi_joint() {
        let m = Mesh::grid(&[("a", 2), ("b", 2), ("c", 2)]);
        let groups = m.groups_multi(&[0, 2]);
        assert_eq!(groups.len(), 2); // one per b-coordinate
        for g in &groups {
            assert_eq!(g.len(), 4);
        }
    }

    #[test]
    fn one_dim_mesh() {
        let m = Mesh::grid(&[("d", 8)]);
        assert_eq!(m.groups(0).len(), 1);
        assert_eq!(m.groups(0)[0].len(), 8);
    }

    #[test]
    fn with_axis_appends_behind_existing_axes() {
        let m = Mesh::grid(&[("a", 2), ("b", 2)]);
        let e = m.with_axis("stage", 3);
        assert_eq!(e.rank(), 3);
        assert_eq!(e.axis_name(0), "a");
        assert_eq!(e.axis_name(2), "stage");
        assert_eq!(e.num_devices(), 12);
        // original mesh untouched
        assert_eq!(m.rank(), 2);
    }

    #[test]
    fn json_roundtrip() {
        let m = Mesh::grid(&[("data", 4), ("model", 2), ("seq", 1)]);
        let back = Mesh::from_json(&Json::parse(&m.to_json().render()).unwrap()).unwrap();
        assert_eq!(back, m);
        assert!(Mesh::from_json(&Json::parse("{\"axes\":[]}").unwrap()).is_err());
        assert!(Mesh::from_json(&Json::parse("{}").unwrap()).is_err());
    }
}
