//! Small in-tree utilities (the environment has no network access, so the
//! usual crates — rand, serde_json — are replaced by these).

pub mod json;
pub mod rng;

pub use rng::Rng;
