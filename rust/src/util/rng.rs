//! SplitMix64 PRNG — deterministic, seedable, dependency-free.
//! Statistical quality is far beyond what action sampling needs.

/// SplitMix64 generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.below(xs.len())])
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..20).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }
}
