//! Minimal JSON emission for reports (no serde available offline).

/// A JSON value builder with string output.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn s(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn n(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    if *v == v.trunc() && v.abs() < 1e15 {
                        out.push_str(&format!("{}", *v as i64));
                    } else {
                        out.push_str(&format!("{v}"));
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj(vec![
            ("name", Json::s("toast")),
            ("n", Json::n(3.0)),
            ("xs", Json::Arr(vec![Json::n(1.5), Json::Bool(true), Json::Null])),
        ]);
        assert_eq!(j.render(), r#"{"name":"toast","n":3,"xs":[1.5,true,null]}"#);
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::s("a\"b\nc").render(), r#""a\"b\nc""#);
    }
}
