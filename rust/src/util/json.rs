//! Minimal JSON emission *and parsing* for reports and wire artifacts
//! (no serde available offline).
//!
//! Rendering and parsing round-trip exactly: `Json::parse(j.render())`
//! reconstructs `j` for every finite value (non-finite numbers render as
//! `null`), and f64s survive because [`Json::render`] emits Rust's
//! shortest round-trip `Display` form and [`Json::parse`] reads it back
//! with the correctly-rounded `str::parse::<f64>`. This is what lets
//! [`crate::api`] guarantee serialized sharding artifacts reload to the
//! exact same spec and cost.

/// A JSON value builder with string output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn s(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn n(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- accessors (None on kind mismatch) ------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Number as usize; None if negative, fractional or not a number.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(v) if *v >= 0.0 && *v == v.trunc() && *v <= u64::MAX as f64 => {
                Some(*v as usize)
            }
            _ => None,
        }
    }

    /// Number as u64; None if negative, fractional or not a number.
    /// (Counters above 2^53 lose f64 precision — the wire layer
    /// string-encodes those; this accessor is for in-range telemetry.)
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && *v == v.trunc() && *v <= u64::MAX as f64 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Object field lookup (first match; None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    // ---- parsing --------------------------------------------------------

    /// Parse a JSON document. Accepts exactly what [`Json::render`] emits
    /// plus standard JSON whitespace/escapes; rejects trailing garbage.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { text, bytes, pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Parse a JSON document from raw bytes — the framing layer hands
    /// payloads around as byte buffers. UTF-8 validation happens here so
    /// callers get a positioned [`JsonError`] instead of a panic.
    pub fn parse_slice(bytes: &[u8]) -> Result<Json, JsonError> {
        match std::str::from_utf8(bytes) {
            Ok(text) => Json::parse(text),
            Err(e) => Err(JsonError {
                pos: e.valid_up_to(),
                msg: "invalid UTF-8 in JSON payload".to_string(),
            }),
        }
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    if *v == v.trunc() && v.abs() < 1e15 {
                        out.push_str(&format!("{}", *v as i64));
                    } else {
                        out.push_str(&format!("{v}"));
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// A JSON parse error with byte position context.
#[derive(Clone, Debug, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Nesting cap for untrusted input: far above any artifact this crate
/// emits (a `Solution` nests ~6 levels), far below stack exhaustion.
const MAX_DEPTH: usize = 256;

struct Parser<'a> {
    text: &'a str,
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn eat_lit(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.text[self.pos..].starts_with(lit) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.depth += 1;
        let v = match self.peek() {
            Some(b'n') => self.eat_lit("null", Json::Null),
            Some(b't') => self.eat_lit("true", Json::Bool(true)),
            Some(b'f') => self.eat_lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        };
        self.depth -= 1;
        v
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        self.text[start..self.pos]
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| self.err(format!("bad number '{}': {e}", &self.text[start..self.pos])))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            // Fast path: copy the longest escape-free run in one go.
            let run_start = self.pos;
            while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                self.pos += 1;
            }
            if self.pos > run_start {
                // Guard against splitting a UTF-8 sequence: runs end only
                // at ASCII '"' or '\\', which never occur mid-codepoint.
                out.push_str(
                    std::str::from_utf8(&self.bytes[run_start..self.pos])
                        .map_err(|_| self.err("invalid UTF-8 in string"))?,
                );
            }
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .text
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err(format!("bad \\u escape '{hex}'")))?;
                            self.pos += 4; // now on the last hex digit
                            if (0xD800..0xDC00).contains(&code) {
                                // High surrogate: standard JSON encoders
                                // (serde_json, Python's json) emit non-BMP
                                // chars as a \uXXXX\uXXXX pair — combine
                                // with the following low surrogate.
                                if self.text[self.pos + 1..].starts_with("\\u") {
                                    if let Some(lo_hex) =
                                        self.text.get(self.pos + 3..self.pos + 7)
                                    {
                                        if let Ok(lo) = u32::from_str_radix(lo_hex, 16) {
                                            if (0xDC00..0xE000).contains(&lo) {
                                                let c = 0x10000
                                                    + ((code - 0xD800) << 10)
                                                    + (lo - 0xDC00);
                                                out.push(
                                                    char::from_u32(c).unwrap_or('\u{fffd}'),
                                                );
                                                self.pos += 6;
                                            } else {
                                                out.push('\u{fffd}'); // unpaired high
                                            }
                                        } else {
                                            out.push('\u{fffd}');
                                        }
                                    } else {
                                        out.push('\u{fffd}');
                                    }
                                } else {
                                    out.push('\u{fffd}'); // unpaired high surrogate
                                }
                            } else {
                                // Lone low surrogates map to U+FFFD like
                                // serde's lossy mode; everything else is a
                                // scalar value.
                                out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            }
                        }
                        _ => return Err(self.err("bad escape sequence")),
                    }
                    self.pos += 1;
                }
                _ => return Err(self.err("unterminated string")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj(vec![
            ("name", Json::s("toast")),
            ("n", Json::n(3.0)),
            ("xs", Json::Arr(vec![Json::n(1.5), Json::Bool(true), Json::Null])),
        ]);
        assert_eq!(j.render(), r#"{"name":"toast","n":3,"xs":[1.5,true,null]}"#);
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::s("a\"b\nc").render(), r#""a\"b\nc""#);
    }

    #[test]
    fn parses_what_it_renders() {
        let j = Json::obj(vec![
            ("name", Json::s("toast \"quoted\"\n\ttabbed")),
            ("n", Json::n(3.0)),
            ("neg", Json::n(-17.25)),
            ("tiny", Json::n(1.0e-4)),
            ("pi", Json::n(std::f64::consts::PI)),
            ("big", Json::n(1.2345678901234567e300)),
            ("xs", Json::Arr(vec![Json::n(1.5), Json::Bool(true), Json::Null])),
            ("empty_arr", Json::Arr(vec![])),
            ("empty_obj", Json::Obj(vec![])),
        ]);
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parses_whitespace_and_escapes() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5e1 , \"x\\u0041\" ] , \"b\" : null } ")
            .unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(25.0));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[2].as_str(), Some("xA"));
        assert!(j.get("b").unwrap().is_null());
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn surrogate_pairs_combine() {
        // serde_json/Python emit non-BMP chars as \u pairs: U+1D703.
        let j = Json::parse(r#""\ud835\udf03x""#).unwrap();
        assert_eq!(j.as_str(), Some("\u{1D703}x"));
        // Unpaired surrogates degrade to U+FFFD, not errors.
        assert_eq!(Json::parse(r#""\ud835""#).unwrap().as_str(), Some("\u{fffd}"));
        assert_eq!(Json::parse(r#""\udf03""#).unwrap().as_str(), Some("\u{fffd}"));
        // High surrogate followed by a non-surrogate escape: FFFD + the char.
        assert_eq!(
            Json::parse(r#""\ud835A""#).unwrap().as_str(),
            Some("\u{fffd}A")
        );
        // Non-BMP chars also pass through raw and re-render as themselves.
        let raw = Json::s("\u{1D703}");
        assert_eq!(Json::parse(&raw.render()).unwrap(), raw);
    }

    #[test]
    fn f64_roundtrip_is_exact() {
        // (-0.0 is excluded: the renderer's integer fast path prints it
        // as `0`, which reads back as +0.0 — equal, different bits.)
        for v in [0.1, 1.0 / 3.0, f64::MAX, f64::MIN_POSITIVE, 123456789.0_f64] {
            let s = Json::n(v).render();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "value {v} via '{s}'");
        }
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"abc", "{}x", "[01]x"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn nesting_depth_is_bounded() {
        let deep = "[".repeat(100_000) + &"]".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.msg.contains("nesting too deep"), "{err}");
        // Reasonable nesting still parses.
        let ok = "[".repeat(64) + "1" + &"]".repeat(64);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn parse_slice_checks_utf8() {
        assert_eq!(Json::parse_slice(b"{\"a\":1}").unwrap().get("a").unwrap().as_f64(), Some(1.0));
        let err = Json::parse_slice(&[b'"', 0xFF, b'"']).unwrap_err();
        assert!(err.msg.contains("UTF-8"), "{err}");
    }

    #[test]
    fn accessor_kinds() {
        assert_eq!(Json::n(7.0).as_usize(), Some(7));
        assert_eq!(Json::n(-1.0).as_usize(), None);
        assert_eq!(Json::n(1.5).as_usize(), None);
        assert_eq!(Json::n(7.0).as_u64(), Some(7));
        assert_eq!(Json::n(-1.0).as_u64(), None);
        assert_eq!(Json::n(1.5).as_u64(), None);
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
        assert_eq!(Json::s("x").as_f64(), None);
    }
}
