//! End-to-end runtime tests over the AOT artifacts.
//!
//! These prove the three-layer composition on the *real* XLA runtime:
//! the L1 Pallas kernel and L2 JAX model, AOT-lowered to HLO text, load
//! and execute through the Rust PJRT client, and the L3 data-parallel
//! coordinator reproduces single-device numerics exactly.
//!
//! They require the artifacts produced by `make artifacts`, which a
//! plain checkout does not have — so they are `#[ignore]`d by default
//! and CI output reports them as *ignored*, never as spuriously passed
//! (the old behavior returned early with an `eprintln!`, which counted
//! as success). Opting in takes both halves — the env var asserts the
//! environment is prepared, `--include-ignored` actually selects the
//! tests:
//!
//! ```text
//! make artifacts
//! PALLAS_E2E=1 cargo test --test runtime_e2e -- --include-ignored
//! ```
//!
//! Once selected, anything short of a fully prepared environment
//! (unset `PALLAS_E2E`, missing artifact directory) is a hard failure
//! with instructions — never a silent skip.

use toast::runtime::simexec::DataParallelTrainer;
use toast::runtime::Runtime;

/// Enforce the opt-in contract and resolve the artifacts directory, or
/// fail loudly. `PALLAS_E2E_DIR` overrides the default
/// `<manifest>/artifacts` location.
fn artifacts_dir() -> std::path::PathBuf {
    assert!(
        std::env::var("PALLAS_E2E").map(|v| v != "0" && !v.is_empty()).unwrap_or(false),
        "runtime_e2e tests are opt-in: set PALLAS_E2E=1 (after `make artifacts`) \
         and run with --include-ignored"
    );
    let dir = match std::env::var("PALLAS_E2E_DIR") {
        Ok(d) => std::path::PathBuf::from(d),
        Err(_) => std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    };
    assert!(
        dir.join("manifest.json").exists(),
        "PALLAS_E2E=1 but no AOT artifacts at {} — run `make artifacts` first",
        dir.display()
    );
    dir
}

#[test]
#[ignore = "needs AOT artifacts (PALLAS_E2E=1 + make artifacts); see module docs"]
fn artifacts_load_and_forward_runs() {
    let rt = Runtime::load_dir(artifacts_dir()).unwrap();
    assert!(rt.artifacts.contains_key("fwd"));
    assert!(rt.artifacts.contains_key("grad"));
    assert!(rt.artifacts.contains_key("adam"));
    assert!(rt.artifacts.contains_key("kernel_attn"));
    assert!(!rt.manifest.param_names.is_empty());
}

#[test]
#[ignore = "needs AOT artifacts (PALLAS_E2E=1 + make artifacts); see module docs"]
fn kernel_artifact_computes_attention() {
    let rt = Runtime::load_dir(artifacts_dir()).unwrap();
    let cfg = &rt.manifest.config;
    let (b, h, s, k) = (
        cfg["batch"] as usize,
        cfg["heads"] as usize,
        cfg["seq"] as usize,
        cfg["key_size"] as usize,
    );
    let n = b * h * s * k;
    // uniform V => attention output must equal V everywhere
    let q = xla::Literal::vec1(&vec![0.1f32; n])
        .reshape(&[b as i64, h as i64, s as i64, k as i64])
        .unwrap();
    let kk = xla::Literal::vec1(&vec![0.2f32; n])
        .reshape(&[b as i64, h as i64, s as i64, k as i64])
        .unwrap();
    let v = xla::Literal::vec1(&vec![3.5f32; n])
        .reshape(&[b as i64, h as i64, s as i64, k as i64])
        .unwrap();
    let outs = rt.execute("kernel_attn", &[q, kk, v]).unwrap();
    let data = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(data.len(), n);
    for &x in data.iter().step_by(97) {
        assert!((x - 3.5).abs() < 1e-4, "attention of uniform V must be V, got {x}");
    }
}

#[test]
#[ignore = "needs AOT artifacts (PALLAS_E2E=1 + make artifacts); see module docs"]
fn data_parallel_matches_single_device() {
    let rt = Runtime::load_dir(artifacts_dir()).unwrap();
    let steps = 3;
    let mut t1 = DataParallelTrainer::new(&rt, 1, 99).unwrap();
    let r1 = t1.train(steps, 2).unwrap();
    let mut t2 = DataParallelTrainer::new(&rt, 2, 99).unwrap();
    let r2 = t2.train(steps, 2).unwrap();
    for (a, b) in r1.losses.iter().zip(&r2.losses) {
        assert!(
            (a - b).abs() < 1e-3,
            "1-device vs 2-device loss diverged: {a} vs {b}"
        );
    }
}

#[test]
#[ignore = "needs AOT artifacts (PALLAS_E2E=1 + make artifacts); see module docs"]
fn invalid_device_counts_rejected() {
    let rt = Runtime::load_dir(artifacts_dir()).unwrap();
    assert!(DataParallelTrainer::new(&rt, 3, 0).is_err());
    assert!(DataParallelTrainer::new(&rt, 16, 0).is_err());
}
