//! Golden-snapshot tests pinning the §3 static analysis across the
//! model zoo: per-model color count, conflict count, compatibility-set
//! count, resolution-group count, parameter-group count, and the
//! pipeline subsystem's legal stage-cut count (the boundaries
//! `toast::pipeline::legal_boundaries` enumerates from the NDA).
//!
//! The snapshot lives at `rust/tests/golden/nda_zoo.snap`. On first run
//! (or with `GOLDEN_BLESS=1`) the current analysis is written out and
//! the test passes; afterwards any refactor that shifts the analysis
//! fails with a per-model, per-metric diff naming exactly what moved —
//! re-bless deliberately with `GOLDEN_BLESS=1 cargo test --test
//! golden_nda` after confirming the shift is intended.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;

use toast::models::ModelKind;
use toast::nda::Nda;

const METRICS: [&str; 6] =
    ["colors", "conflicts", "compat_sets", "resolution_groups", "param_groups", "stage_cuts"];

fn snapshot_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/nda_zoo.snap")
}

/// One model's metric line, e.g.
/// `mlp colors=12 conflicts=3 compat_sets=2 resolution_groups=1 param_groups=4`.
fn summarize(kind: ModelKind) -> BTreeMap<&'static str, usize> {
    let func = kind.build_scaled();
    let nda = Nda::analyze(&func);
    let mut m = BTreeMap::new();
    m.insert("colors", nda.num_colors());
    m.insert("conflicts", nda.conflicts.conflicts.len());
    m.insert("compat_sets", nda.conflicts.compat_sets.len());
    m.insert("resolution_groups", nda.conflicts.num_groups());
    m.insert("param_groups", nda.param_groups.len());
    m.insert("stage_cuts", toast::pipeline::legal_boundaries(&func, &nda).len());
    m
}

fn render() -> String {
    let mut out = String::new();
    for &kind in ModelKind::all() {
        let m = summarize(kind);
        let _ = write!(out, "{}", kind.name());
        for key in METRICS {
            let _ = write!(out, " {}={}", key, m[key]);
        }
        out.push('\n');
    }
    out
}

fn parse(text: &str) -> BTreeMap<String, BTreeMap<String, usize>> {
    let mut models = BTreeMap::new();
    for line in text.lines() {
        let mut parts = line.split_whitespace();
        let Some(model) = parts.next() else { continue };
        let mut metrics = BTreeMap::new();
        for kv in parts {
            if let Some((k, v)) = kv.split_once('=') {
                if let Ok(n) = v.parse::<usize>() {
                    metrics.insert(k.to_string(), n);
                }
            }
        }
        models.insert(model.to_string(), metrics);
    }
    models
}

/// Readable diff between two snapshots; empty when identical.
fn diff(golden: &str, current: &str) -> String {
    let g = parse(golden);
    let c = parse(current);
    let mut out = String::new();
    for (model, gm) in &g {
        match c.get(model) {
            None => {
                let _ = writeln!(out, "  model {model}: missing from current analysis");
            }
            Some(cm) => {
                for key in METRICS {
                    let gv = gm.get(key).copied().unwrap_or(0);
                    let cv = cm.get(key).copied().unwrap_or(0);
                    if gv != cv {
                        let _ = writeln!(
                            out,
                            "  model {model}: {key} {gv} -> {cv} ({:+})",
                            cv as i64 - gv as i64
                        );
                    }
                }
            }
        }
    }
    for model in c.keys() {
        if !g.contains_key(model) {
            let _ = writeln!(out, "  model {model}: new in current analysis");
        }
    }
    out
}

/// The analysis itself must be deterministic run-to-run, or a snapshot
/// is meaningless.
#[test]
fn nda_zoo_summary_is_deterministic() {
    assert_eq!(render(), render(), "NDA summary differs between two in-process runs");
}

#[test]
fn nda_zoo_matches_golden_snapshot() {
    let path = snapshot_path();
    let current = render();
    let bless = std::env::var("GOLDEN_BLESS")
        .map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false);
    let golden = if bless { None } else { std::fs::read_to_string(&path).ok() };
    match golden {
        None => {
            std::fs::create_dir_all(path.parent().unwrap())
                .expect("create golden snapshot directory");
            std::fs::write(&path, &current).expect("write golden snapshot");
            eprintln!(
                "blessed NDA golden snapshot at {} ({} models){}",
                path.display(),
                current.lines().count(),
                if bless { " [GOLDEN_BLESS]" } else { " [first run]" }
            );
        }
        Some(golden) => {
            let d = diff(&golden, &current);
            assert!(
                d.is_empty(),
                "§3 static analysis shifted from the golden snapshot \
                 ({}):\n{}\nIf intended, re-bless with GOLDEN_BLESS=1.",
                path.display(),
                d
            );
        }
    }
}
