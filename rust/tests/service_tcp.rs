//! Integration tests for the socket transport: real localhost sockets,
//! real worker loops, crash-and-requeue semantics, and the
//! transports-cannot-drift guarantee (thread mode and socket mode
//! produce identical solutions).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use toast::api::wire::{Message, StatusReport};
use toast::api::{
    CompiledModel, ModelSource, PartitionRequest, PartitionResponse, Solution, ValidationRecord,
};
use toast::baselines::Method;
use toast::coordinator::metrics::Metrics;
use toast::coordinator::service::default_request;
use toast::coordinator::transport::{
    read_frame, read_message, run_worker_on, write_frame, write_message, MAX_FRAME_LEN,
};
use toast::coordinator::{
    Overloaded, Service, ServiceClient, ServiceConfig, TcpServer, TcpServerConfig, WorkerOptions,
};
use toast::mesh::{HardwareKind, Mesh, Topology};
use toast::models::ModelKind;
use toast::util::rng::Rng;

/// Start a socket server over an explicitly configured service. Returns
/// the bound address, a metrics handle, and the server (shut it down to
/// end the worker loops cleanly).
fn start_server_with(
    svc_cfg: ServiceConfig,
    tcp_cfg: TcpServerConfig,
) -> (SocketAddr, Arc<Metrics>, TcpServer) {
    let svc = Service::start_with(svc_cfg);
    let metrics = Arc::clone(&svc.metrics);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind an ephemeral port");
    let server = TcpServer::start(svc, listener, tcp_cfg).unwrap();
    (server.local_addr(), metrics, server)
}

/// The common shape: deterministic single-threaded searches, default
/// cache/admission, single-slot workers.
fn start_server(local_workers: usize, dead_after: Duration) -> (SocketAddr, Arc<Metrics>, TcpServer) {
    start_server_with(
        ServiceConfig { workers: local_workers, search_threads: 1, ..Default::default() },
        TcpServerConfig { dead_after, ..Default::default() },
    )
}

fn deterministic_worker(name: &str) -> WorkerOptions {
    WorkerOptions {
        name: name.to_string(),
        service: ServiceConfig { workers: 0, search_threads: 1, ..Default::default() },
    }
}

fn random_request(rng: &mut Rng) -> PartitionRequest {
    let kinds = ModelKind::all();
    let meshes = [
        Mesh::grid(&[("data", 2), ("model", 2)]),
        Mesh::grid(&[("data", 4)]),
        Mesh::grid(&[("a", 2), ("b", 2), ("c", 2)]),
    ];
    let methods = Method::all();
    PartitionRequest {
        id: rng.next_u64(),
        model: ModelSource::zoo(*rng.choose(&kinds).unwrap()),
        mesh: rng.choose(&meshes).unwrap().clone(),
        topology: Topology::from_kind(*rng.choose(&HardwareKind::all()).unwrap()),
        method: *rng.choose(&methods).unwrap(),
        budget: rng.below(2000),
        // Half the seeds exceed 2^53 to exercise the string encoding.
        seed: if rng.below(2) == 0 { rng.below(1000) as u64 } else { rng.next_u64() | (1 << 60) },
        verify: rng.below(2) == 0,
        no_cache: rng.below(2) == 0,
    }
}

fn assert_request_eq(a: &PartitionRequest, b: &PartitionRequest) {
    assert_eq!(a.id, b.id);
    assert_eq!(a.model, b.model);
    assert_eq!(a.mesh, b.mesh);
    assert_eq!(a.topology, b.topology);
    assert_eq!(a.method, b.method);
    assert_eq!(a.budget, b.budget);
    assert_eq!(a.seed, b.seed);
    assert_eq!(a.verify, b.verify);
    assert_eq!(a.no_cache, b.no_cache);
}

/// Property-style round-trip of request/response/status frames through a
/// real localhost socket pair (an echo peer), covering randomized
/// payloads, a real solution artifact, and an error response.
#[test]
fn frames_roundtrip_through_a_real_socket_pair() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let echo = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut rd = stream.try_clone().unwrap();
        let mut wr = stream;
        while let Some(bytes) = read_frame(&mut rd, MAX_FRAME_LEN).unwrap() {
            write_frame(&mut wr, &bytes).unwrap();
        }
    });

    let stream = TcpStream::connect(addr).unwrap();
    let mut rd = stream.try_clone().unwrap();
    let mut wr = stream;
    let mut rng = Rng::new(0xC0FFEE);

    for _ in 0..24 {
        let req = random_request(&mut rng);
        write_message(&mut wr, &Message::Submit(req.clone())).unwrap();
        match read_message(&mut rd, MAX_FRAME_LEN).unwrap().unwrap() {
            Message::Submit(back) => assert_request_eq(&back, &req),
            other => panic!("expected submit back, got '{}'", other.tag()),
        }
    }

    // A response carrying a real, validated solution round-trips exactly.
    let compiled = CompiledModel::from_kind(ModelKind::Mlp, false).unwrap();
    let mesh = Mesh::grid(&[("data", 2), ("model", 2)]);
    let sol = compiled.partition(&mesh).budget(40).seed(3).validate(true).run().unwrap();
    let resp = PartitionResponse {
        id: 77,
        request: default_request(ModelKind::Mlp, Method::Toast),
        result: Ok(sol.clone()),
        rejected: false,
    };
    write_message(&mut wr, &Message::Result(resp)).unwrap();
    match read_message(&mut rd, MAX_FRAME_LEN).unwrap().unwrap() {
        Message::Result(back) => {
            assert_eq!(back.id, 77);
            assert_eq!(back.result.unwrap(), sol, "solution drifted through the socket");
        }
        other => panic!("expected result back, got '{}'", other.tag()),
    }

    // An error response and a status report survive too.
    let resp = PartitionResponse {
        id: 78,
        request: default_request(ModelKind::Attention, Method::Alpa),
        result: Err(anyhow::anyhow!("worker exploded")),
        rejected: true,
    };
    write_message(&mut wr, &Message::Response(resp)).unwrap();
    match read_message(&mut rd, MAX_FRAME_LEN).unwrap().unwrap() {
        Message::Response(back) => {
            assert!(back.rejected);
            assert!(format!("{:#}", back.result.unwrap_err()).contains("worker exploded"));
        }
        other => panic!("expected response back, got '{}'", other.tag()),
    }
    let report = StatusReport { requests: 5, requeued: 2, workers: 3, ..Default::default() };
    write_message(&mut wr, &Message::StatusReport(report.clone())).unwrap();
    match read_message(&mut rd, MAX_FRAME_LEN).unwrap().unwrap() {
        Message::StatusReport(back) => assert_eq!(back, report),
        other => panic!("expected status report back, got '{}'", other.tag()),
    }

    drop(wr); // close the write half so the echo loop sees EOF
    drop(rd);
    echo.join().unwrap();
}

/// Garbage bytes and oversized frames poison only their own connection:
/// the listener keeps accepting and a well-formed client still gets a
/// verified solution afterwards.
#[test]
fn garbage_and_oversized_frames_do_not_kill_the_listener() {
    let (addr, _metrics, server) = start_server(1, Duration::from_secs(5));

    // 1. Raw garbage whose "length prefix" decodes to ~4 GiB.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&[0xFF; 64]).unwrap();
        // The server answers with an error frame (best effort) and
        // closes; reading to EOF must terminate.
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    }

    // 2. A well-framed payload that is not JSON.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        write_frame(&mut s, b"not json at all").unwrap();
        let mut rd = s.try_clone().unwrap();
        match read_message(&mut rd, MAX_FRAME_LEN).unwrap() {
            Some(Message::Error { message }) => {
                assert!(message.contains("bad frame"), "{message}")
            }
            other => panic!("expected an error frame, got {:?}", other.map(|m| m.tag())),
        }
    }

    // 3. A protocol violation: a client starting with a worker-only tag.
    {
        let s = TcpStream::connect(addr).unwrap();
        let mut rd = s.try_clone().unwrap();
        let mut wr = s;
        write_message(&mut wr, &Message::Heartbeat).unwrap();
        match read_message(&mut rd, MAX_FRAME_LEN).unwrap() {
            Some(Message::Error { message }) => {
                assert!(message.contains("protocol error"), "{message}")
            }
            other => panic!("expected an error frame, got {:?}", other.map(|m| m.tag())),
        }
    }

    // 4. The listener survived all of it: a real request still verifies.
    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    let mut req = default_request(ModelKind::Mlp, Method::Manual);
    req.budget = 40;
    let id = client.submit(req).unwrap();
    let resp = client.recv_response().unwrap();
    assert_eq!(resp.id, id);
    let sol = resp.result.expect("job succeeds after the garbage connections");
    assert!(sol.validation.expect("trust-but-verify ran").pass);
    server.shutdown();
}

/// A worker that dies mid-request is detected, its request is requeued
/// (exactly once) and completed by a surviving worker, and the metrics
/// show zero lost requests.
#[test]
fn dead_worker_requeues_in_flight_and_a_survivor_completes() {
    let (addr, metrics, server) = start_server(0, Duration::from_millis(1500));

    // A fake worker that registers, accepts the job, then "crashes"
    // without answering (socket drops on thread exit).
    let crasher = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut rd = stream.try_clone().unwrap();
        let mut wr = stream;
        write_message(&mut wr, &Message::Register { name: "crasher".into() }).unwrap();
        match read_message(&mut rd, MAX_FRAME_LEN).unwrap() {
            Some(Message::Registered { .. }) => {}
            other => panic!("expected registration ack, got {:?}", other.map(|m| m.tag())),
        }
        loop {
            match read_message(&mut rd, MAX_FRAME_LEN).unwrap() {
                Some(Message::Job(req)) => return req.id,
                Some(_) => continue,
                None => panic!("server closed before dispatching the job"),
            }
        }
    });

    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    let mut req = default_request(ModelKind::Mlp, Method::Toast);
    req.budget = 60;
    req.seed = 4;
    let id = client.submit(req).unwrap();

    // The crasher owns the only connection, so it must receive the job —
    // and then it dies.
    let dispatched_id = crasher.join().unwrap();
    assert_eq!(dispatched_id, id);

    // A surviving worker (the *real* worker loop) joins and finishes the
    // requeued request.
    let survivor = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        run_worker_on(stream, &deterministic_worker("survivor")).unwrap();
    });

    let resp = client.recv_response().unwrap();
    assert_eq!(resp.id, id);
    let sol = resp.result.expect("completed by the survivor");
    assert!(
        sol.validation.expect("trust-but-verify ran in the worker process").pass,
        "requeued request must still arrive verified"
    );

    let report = client.status().unwrap();
    assert_eq!(report.requeued, 1, "exactly one requeue: {}", report.render_line());
    assert_eq!(report.completed, 1, "{}", report.render_line());
    assert_eq!(report.failed, 0, "{}", report.render_line());
    assert_eq!(report.queued, 0, "zero lost requests: {}", report.render_line());
    assert_eq!(report.in_flight, 0, "{}", report.render_line());
    assert_eq!(report.verified, 1, "{}", report.render_line());
    assert_eq!(metrics.report().requeued, 1);

    // Shutdown closes the worker socket; the survivor's loop returns Ok.
    server.shutdown();
    survivor.join().unwrap();
}

/// The poison-request guard: a request that keeps killing its workers is
/// requeued at most `MAX_REQUEUES` times, then failed back to the client
/// instead of serially destroying the fleet.
#[test]
fn poison_request_is_failed_after_the_requeue_cap() {
    use toast::coordinator::transport::MAX_REQUEUES;
    let (addr, metrics, server) = start_server(0, Duration::from_secs(5));

    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    let id = client.submit(default_request(ModelKind::Mlp, Method::Manual)).unwrap();

    // The request gets MAX_REQUEUES + 1 chances; every worker "crashes".
    for round in 0..=MAX_REQUEUES {
        let stream = TcpStream::connect(addr).unwrap();
        let mut rd = stream.try_clone().unwrap();
        let mut wr = stream;
        write_message(&mut wr, &Message::Register { name: format!("crasher-{round}") })
            .unwrap();
        match read_message(&mut rd, MAX_FRAME_LEN).unwrap() {
            Some(Message::Registered { .. }) => {}
            other => panic!("expected registration ack, got {:?}", other.map(|m| m.tag())),
        }
        loop {
            match read_message(&mut rd, MAX_FRAME_LEN).unwrap() {
                Some(Message::Job(req)) => {
                    assert_eq!(req.id, id, "the poison request is always dispatched first");
                    break;
                }
                Some(_) => continue,
                None => panic!("server closed before dispatching (round {round})"),
            }
        }
        // Connection drops here — the worker "crashed" mid-request.
    }

    let resp = client.recv_response().unwrap();
    assert_eq!(resp.id, id);
    let err = resp.result.expect_err("the poison request must fail, not hang or loop");
    assert!(format!("{err:#}").contains("giving up"), "{err:#}");

    let report = client.status().unwrap();
    assert_eq!(report.requeued, u64::from(MAX_REQUEUES), "{}", report.render_line());
    assert_eq!(report.failed, 1, "{}", report.render_line());
    assert_eq!(report.completed, 0, "{}", report.render_line());
    assert_eq!(report.queued, 0, "{}", report.render_line());
    assert_eq!(report.in_flight, 0, "{}", report.render_line());
    assert_eq!(metrics.report().requeued, u64::from(MAX_REQUEUES));
    // The regression this test pins down: every terminal path — the
    // give-up failure included — must clear the request's requeue-count
    // ledger entry, or a long-lived server leaks one entry per poison.
    assert_eq!(
        server.pending_requeue_entries(),
        0,
        "requeue ledger must be empty once the poison request is failed"
    );
    server.shutdown();
}

/// Kill-server/restart: a worker running the reconnect loop serves a
/// request, survives the server being torn down, reconnects with
/// exponential backoff to a fresh server on the *same* address, and
/// serves again — the restarted server picks its fleet back up without
/// anyone re-spawning worker processes.
#[test]
fn restarted_server_picks_the_fleet_back_up() {
    use toast::coordinator::transport::{run_worker_reconnect, ReconnectPolicy};

    let (addr, _metrics1, server1) = start_server(0, Duration::from_secs(5));
    let policy = ReconnectPolicy {
        initial: Duration::from_millis(20),
        max: Duration::from_millis(200),
        // Generous enough to ride out the restart window (the rebind
        // happens within a few of the early 20-80ms retries), small
        // enough that the worker exits promptly after the final
        // shutdown instead of probing a freed port for seconds.
        max_attempts: 12,
    };
    let worker = std::thread::spawn({
        let addr = addr.to_string();
        let opts = deterministic_worker("phoenix");
        let policy = policy.clone();
        move || {
            // Spans BOTH server generations; returns Err("giving up...")
            // once the final server is gone and attempts run out.
            let err = run_worker_reconnect(&addr, &opts, &policy)
                .expect_err("reconnect loop only ends by exhausting attempts");
            assert!(format!("{err:#}").contains("giving up"), "{err:#}");
        }
    });

    // Generation 1 serves a request through the reconnecting worker.
    let mut req = default_request(ModelKind::Mlp, Method::Manual);
    req.budget = 40;
    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    let id = client.submit(req.clone()).unwrap();
    let resp = client.recv_response().unwrap();
    assert_eq!(resp.id, id);
    assert!(resp.result.expect("gen-1 job").validation.expect("verified").pass);

    // Kill the server; the worker's connection drops and its backoff
    // loop starts probing the dead address.
    drop(client);
    server1.shutdown();

    // Restart on the SAME address. std listeners set SO_REUSEADDR on
    // Unix, but retry briefly in case the port lingers.
    let listener = {
        let mut attempt = 0;
        loop {
            match TcpListener::bind(addr) {
                Ok(l) => break l,
                Err(e) => {
                    attempt += 1;
                    assert!(attempt < 100, "rebinding {addr} failed: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    };
    let svc = Service::start_with(ServiceConfig {
        workers: 0,
        search_threads: 1,
        ..Default::default()
    });
    let metrics2 = Arc::clone(&svc.metrics);
    let server2 = TcpServer::start(
        svc,
        listener,
        TcpServerConfig { dead_after: Duration::from_secs(5), ..Default::default() },
    )
    .unwrap();
    assert_eq!(server2.local_addr(), addr, "generation 2 must reuse the address");

    // The SAME worker process reconnects (fail fast rather than hang if
    // the backoff loop gave up early).
    let rebind = std::time::Instant::now();
    let mut waited = 0;
    while metrics2.report().workers == 0 {
        waited += 1;
        assert!(waited < 200, "worker never reconnected to the restarted server");
        std::thread::sleep(Duration::from_millis(25));
    }
    // Reconnect latency is bounded by the backoff schedule (max 200ms),
    // not by a heartbeat thread wedged in a blocking write to the dead
    // server: the worker sets a write timeout and joins the heartbeat
    // thread through a shutdown flag, so a torn-down server can never
    // hold a worker hostage past its backoff.
    assert!(
        rebind.elapsed() < Duration::from_secs(3),
        "reconnect took {:?} — heartbeat teardown is blocking the retry loop",
        rebind.elapsed()
    );

    // ...and completes generation 2's request.
    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    let id = client.submit(req).unwrap();
    let resp = client.recv_response().unwrap();
    assert_eq!(resp.id, id);
    assert!(
        resp.result.expect("gen-2 job, served by the reconnected worker")
            .validation
            .expect("verified")
            .pass
    );
    let report = metrics2.report();
    assert_eq!(report.workers, 1, "the restarted server sees the old fleet: {}", report.render_line());
    assert_eq!(report.completed, 1, "{}", report.render_line());

    server2.shutdown();
    worker.join().unwrap();
}

/// The acceptance gate in miniature: for a fixed seed and model, the
/// in-process thread mode and the socket mode produce byte-identical
/// `Solution` JSON (modulo the wall-clock field both modes zero).
#[test]
fn socket_mode_and_thread_mode_produce_identical_solution_json() {
    let canonical = |mut sol: Solution| {
        sol.search_time_s = 0.0;
        sol.to_json_string()
    };
    let mut req = default_request(ModelKind::Attention, Method::Toast);
    req.budget = 80;
    req.seed = 11;

    // Thread mode, single-threaded search for determinism.
    let svc = Service::start_with(ServiceConfig {
        workers: 1,
        search_threads: 1,
        ..Default::default()
    });
    svc.submit(req.clone()).unwrap();
    let local = svc.responses.recv().unwrap().result.expect("thread mode succeeds");
    svc.shutdown();

    // Socket mode with a real worker loop on the other end.
    let (addr, _metrics, server) = start_server(0, Duration::from_secs(5));
    let worker = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        run_worker_on(stream, &deterministic_worker("w0")).unwrap();
    });
    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    client.submit(req).unwrap();
    let remote = client.recv_response().unwrap().result.expect("socket mode succeeds");
    server.shutdown();
    worker.join().unwrap();

    assert!(local.validation.as_ref().is_some_and(|v| v.pass));
    assert_eq!(
        canonical(local),
        canonical(remote),
        "the two transports drifted — they must share one dispatch/verify path"
    );
}

/// A repeated socket submission is answered from the server-side
/// solution cache: the artifact is byte-identical (wall-clock field
/// included — an exact clone, so no second search ran), the hit/miss
/// counters move, and `--no-cache` still forces a fresh search.
#[test]
fn warm_cache_socket_submit_is_byte_identical_with_zero_extra_searches() {
    let (addr, _metrics, server) = start_server(0, Duration::from_secs(5));
    let worker = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        run_worker_on(stream, &deterministic_worker("w0")).unwrap();
    });

    let mut req = default_request(ModelKind::Mlp, Method::Toast);
    req.budget = 60;
    req.seed = 9;

    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    client.submit(req.clone()).unwrap();
    let cold = client.recv_response().unwrap().result.expect("cold request succeeds");

    client.submit(req.clone()).unwrap();
    let warm = client.recv_response().unwrap().result.expect("warm request succeeds");

    assert_eq!(
        cold.to_json_string(),
        warm.to_json_string(),
        "a cache hit must be byte-identical to the search it replays"
    );
    assert!(warm.validation.as_ref().is_some_and(|v| v.pass), "hits stay verified");

    let report = client.status().unwrap();
    assert_eq!(report.cache_hits, 1, "{}", report.render_line());
    assert_eq!(report.cache_misses, 1, "{}", report.render_line());
    assert_eq!(report.cache_size, 1, "{}", report.render_line());
    assert_eq!(report.completed, 2, "{}", report.render_line());

    // --no-cache bypasses the cache: a fresh deterministic search runs
    // and agrees with the cached artifact modulo wall clock.
    req.no_cache = true;
    client.submit(req).unwrap();
    let fresh = client.recv_response().unwrap().result.expect("no-cache request succeeds");
    let canonical = |mut sol: Solution| {
        sol.search_time_s = 0.0;
        sol.to_json_string()
    };
    assert_eq!(canonical(cold), canonical(fresh), "deterministic searches must agree");
    let report = client.status().unwrap();
    assert_eq!(report.cache_hits, 1, "no-cache must not hit: {}", report.render_line());
    assert_eq!(report.cache_misses, 1, "no-cache skips the lookup: {}", report.render_line());

    server.shutdown();
    worker.join().unwrap();
}

/// A Byzantine worker cannot forge its validation record: with
/// `audit_fraction` 1.0 the server replays every worker-claimed record
/// through its own differential harness and rejects — and never caches —
/// a response whose claim does not reproduce.
#[test]
fn forged_validation_record_is_rejected_by_the_server_audit() {
    let (addr, metrics, server) = start_server_with(
        ServiceConfig { workers: 0, search_threads: 1, ..Default::default() },
        TcpServerConfig { audit_fraction: 1.0, ..Default::default() },
    );

    // The forger answers an MLP request with a solution searched on a
    // *different* model, stapling on a pass=true record it never earned.
    // Without the server-side replay this would be accepted, cached, and
    // served to every future client of the same request key.
    let byzantine = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut rd = stream.try_clone().unwrap();
        let mut wr = stream;
        write_message(&mut wr, &Message::Register { name: "byzantine".into() }).unwrap();
        match read_message(&mut rd, MAX_FRAME_LEN).unwrap() {
            Some(Message::Registered { .. }) => {}
            other => panic!("expected registration ack, got {:?}", other.map(|m| m.tag())),
        }
        let req = loop {
            match read_message(&mut rd, MAX_FRAME_LEN).unwrap() {
                Some(Message::Job(req)) => break req,
                Some(_) => continue,
                None => panic!("server closed before dispatching the job"),
            }
        };
        let compiled = CompiledModel::from_kind(ModelKind::Attention, false).unwrap();
        let mut sol = compiled
            .partition(&req.mesh)
            .budget(40)
            .seed(3)
            .run()
            .expect("the forger can run an honest search on the wrong model");
        sol.validation = Some(ValidationRecord {
            max_rel_err: 0.0,
            max_abs_diff: 0.0,
            collectives: 0,
            tol: 1e-3,
            pass: true,
            seed: req.seed,
        });
        let resp =
            PartitionResponse { id: req.id, request: req, result: Ok(sol), rejected: false };
        write_message(&mut wr, &Message::Result(resp)).unwrap();
        // Stay connected until the server tears the socket down, so the
        // liveness monitor never mistakes this for a crash-and-requeue.
        while let Ok(Some(_)) = read_message(&mut rd, MAX_FRAME_LEN) {}
    });

    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    let id = client.submit(default_request(ModelKind::Mlp, Method::Toast)).unwrap();
    let resp = client.recv_response().unwrap();
    assert_eq!(resp.id, id);
    assert!(resp.rejected, "a forged record must come back rejected");
    let err = resp.result.expect_err("the forged response must fail, not pass through");
    assert!(format!("{err:#}").contains("audit rejected"), "{err:#}");

    let report = client.status().unwrap();
    assert_eq!(report.audited, 1, "{}", report.render_line());
    assert_eq!(report.audit_rejected, 1, "{}", report.render_line());
    assert_eq!(report.completed, 0, "{}", report.render_line());
    assert_eq!(report.failed, 1, "{}", report.render_line());
    assert_eq!(metrics.report().audit_rejected, 1);
    server.shutdown();
    byzantine.join().unwrap();
}

/// With an admission bound configured, a full queue refuses socket
/// submissions with a structured, typed `overloaded` error — and once
/// the queue drains, the same client's retry is accepted.
#[test]
fn overloaded_submission_is_refused_and_accepted_after_draining() {
    let (addr, _metrics, server) = start_server_with(
        ServiceConfig { workers: 0, search_threads: 1, max_queue: 1, ..Default::default() },
        TcpServerConfig::default(),
    );

    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    let mut req = default_request(ModelKind::Mlp, Method::Manual);
    req.budget = 40;
    let first = client.submit(req.clone()).unwrap();

    // No worker is connected, so the first request sits in the queue and
    // a second, distinct submission hits the bound.
    let mut retry = req.clone();
    retry.seed = 99;
    let err = client.submit(retry.clone()).expect_err("the admission bound must refuse");
    let overloaded = err.downcast_ref::<Overloaded>().expect("typed overload error");
    assert_eq!(overloaded.queued, 1);
    assert_eq!(overloaded.limit, 1);
    assert!(format!("{err:#}").contains("overloaded"), "{err:#}");

    // A worker drains the queue...
    let worker = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        run_worker_on(stream, &deterministic_worker("drainer")).unwrap();
    });
    let resp = client.recv_response().unwrap();
    assert_eq!(resp.id, first);
    assert!(resp.result.expect("first request completes").validation.expect("verified").pass);

    // ...and the refused request is accepted on retry.
    let id = client.submit(retry).unwrap();
    let resp = client.recv_response().unwrap();
    assert_eq!(resp.id, id);
    assert!(resp.result.expect("retried request completes").validation.is_some());

    let report = client.status().unwrap();
    assert_eq!(report.overloaded, 1, "{}", report.render_line());
    assert_eq!(report.completed, 2, "{}", report.render_line());
    server.shutdown();
    worker.join().unwrap();
}

/// A capacity-2 worker that dies with two pipelined jobs in flight gets
/// BOTH requeued — each exactly once — a survivor completes them, and
/// the requeue ledger is empty afterwards.
#[test]
fn multi_job_worker_death_requeues_every_in_flight_job_exactly_once() {
    let (addr, metrics, server) = start_server_with(
        ServiceConfig { workers: 0, search_threads: 1, ..Default::default() },
        TcpServerConfig {
            capacity: 2,
            dead_after: Duration::from_millis(1500),
            ..Default::default()
        },
    );

    // A crasher that accepts both pipelined jobs before dying.
    let crasher = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut rd = stream.try_clone().unwrap();
        let mut wr = stream;
        write_message(&mut wr, &Message::Register { name: "crasher".into() }).unwrap();
        match read_message(&mut rd, MAX_FRAME_LEN).unwrap() {
            Some(Message::Registered { .. }) => {}
            other => panic!("expected registration ack, got {:?}", other.map(|m| m.tag())),
        }
        let mut got = Vec::new();
        while got.len() < 2 {
            match read_message(&mut rd, MAX_FRAME_LEN).unwrap() {
                Some(Message::Job(req)) => got.push(req.id),
                Some(_) => continue,
                None => panic!("server closed before pipelining both jobs"),
            }
        }
        got
    });

    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    let mut req1 = default_request(ModelKind::Mlp, Method::Toast);
    req1.budget = 60;
    let mut req2 = default_request(ModelKind::Mlp, Method::Manual);
    req2.budget = 60;
    let id1 = client.submit(req1).unwrap();
    let id2 = client.submit(req2).unwrap();

    // Capacity 2 pipelines both jobs onto the one connection; then it
    // dies with both in flight.
    let mut dispatched = crasher.join().unwrap();
    dispatched.sort_unstable();
    let mut expected = vec![id1, id2];
    expected.sort_unstable();
    assert_eq!(dispatched, expected, "both jobs must be in flight on the crasher");

    let survivor = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        run_worker_on(stream, &deterministic_worker("survivor")).unwrap();
    });

    let mut seen = Vec::new();
    for _ in 0..2 {
        let resp = client.recv_response().unwrap();
        assert!(resp.result.expect("completed by the survivor").validation.is_some());
        seen.push(resp.id);
    }
    seen.sort_unstable();
    assert_eq!(seen, expected, "every in-flight job completes after the requeue");

    let report = client.status().unwrap();
    assert_eq!(report.requeued, 2, "each job requeued exactly once: {}", report.render_line());
    assert_eq!(report.completed, 2, "{}", report.render_line());
    assert_eq!(report.failed, 0, "{}", report.render_line());
    assert_eq!(report.queued, 0, "{}", report.render_line());
    assert_eq!(report.in_flight, 0, "{}", report.render_line());
    assert_eq!(metrics.report().requeued, 2);
    assert_eq!(server.pending_requeue_entries(), 0, "ledger clears on completion");
    server.shutdown();
    survivor.join().unwrap();
}

/// The observability surface over the socket: after a cold search and a
/// cache hit, a `metrics` request answers well-formed Prometheus text
/// exposition with histogram buckets for BOTH latency phases, and the
/// status report carries per-worker detail for the connected fleet.
#[test]
fn metrics_request_serves_prometheus_exposition_with_phase_histograms() {
    let (addr, _metrics, server) = start_server(0, Duration::from_secs(5));
    let worker = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        run_worker_on(stream, &deterministic_worker("prom-worker")).unwrap();
    });

    let mut req = default_request(ModelKind::Mlp, Method::Toast);
    req.budget = 60;
    req.seed = 21;
    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    client.submit(req.clone()).unwrap();
    client.recv_response().unwrap().result.expect("cold request succeeds");
    client.submit(req).unwrap();
    client.recv_response().unwrap().result.expect("cache hit succeeds");

    let prom = client.metrics_prom().unwrap();
    assert!(prom.contains("# TYPE toast_requests_total counter"), "{prom}");
    assert!(prom.contains("toast_requests_total 2"), "{prom}");
    assert!(prom.contains("# TYPE toast_request_latency_us histogram"), "{prom}");
    assert!(
        prom.contains("toast_request_latency_us_bucket{phase=\"search_cold\",le="),
        "cold search latency must be in the exposition: {prom}"
    );
    assert!(
        prom.contains("toast_request_latency_us_bucket{phase=\"cache_hit\",le="),
        "cache-hit latency must be in the exposition: {prom}"
    );
    assert!(prom.contains("toast_request_latency_us_count{phase=\"search_cold\"} 1"), "{prom}");
    assert!(prom.contains("toast_request_latency_us_count{phase=\"cache_hit\"} 1"), "{prom}");
    // Well-formed: every non-comment line is `name{labels} value` with a
    // parseable numeric value.
    for line in prom.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (_, value) = line.rsplit_once(' ').expect("metric line has a value");
        assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
    }

    // The same client sees the fleet in the status report.
    let report = client.status().unwrap();
    assert_eq!(report.workers_detail.len(), 1, "{}", report.render_workers());
    let w = &report.workers_detail[0];
    assert_eq!(w.name, "prom-worker");
    assert_eq!(w.capacity, 1);
    assert_eq!(w.in_flight, 0);
    assert_eq!(w.completed, 1, "the cache hit never reached the worker");
    assert!(report.latency.iter().any(|l| l.phase == "search_cold" && l.count == 1));
    assert!(report.latency.iter().any(|l| l.phase == "cache_hit" && l.count == 1));

    server.shutdown();
    worker.join().unwrap();
}
