//! Integration tests for the observability subsystem: tracing must be
//! a pure observer (identical solutions with it on or off), and a
//! traced zoo search must emit a Chrome trace-event document that
//! round-trips through the hand-rolled JSON parser.

use std::sync::{Mutex, MutexGuard, OnceLock};

use toast::api::{CompiledModel, MctsStrategy, Solution};
use toast::mesh::Mesh;
use toast::models::ModelKind;
use toast::search::SearchConfig;
use toast::util::json::Json;

/// The trace ring and its enable flag are process-global; serialize the
/// tests that touch them (cargo runs tests in this binary in parallel).
fn obs_guard() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn run_zoo_search(kind: ModelKind, mesh: &Mesh, trace: bool) -> Solution {
    let compiled = CompiledModel::from_kind(kind, false).unwrap();
    compiled
        .partition(mesh)
        // Single-threaded search: bit-reproducible, so byte-identity is
        // a meaningful assertion.
        .strategy(MctsStrategy { template: SearchConfig { threads: 1, ..Default::default() } })
        .budget(80)
        .seed(11)
        .trace(trace)
        .run()
        .expect("zoo search succeeds")
}

/// Tracing is observation, never steering: the same deterministic
/// search with telemetry on — and the global ring enabled — produces a
/// byte-identical solution artifact once the trace attachment itself is
/// stripped. (Wall clock is zeroed the same way the transport-parity
/// tests do; it is nondeterministic with or without tracing.)
#[test]
fn solutions_with_tracing_on_and_off_are_byte_identical() {
    let _g = obs_guard();
    let canonical = |mut sol: Solution| {
        sol.search_time_s = 0.0;
        sol.trace = None;
        sol.to_json_string()
    };
    let mesh = Mesh::grid(&[("data", 2), ("model", 2)]);

    let plain = run_zoo_search(ModelKind::Attention, &mesh, false);
    assert!(plain.trace.is_none(), "untraced sessions must not attach telemetry");

    toast::obs::set_enabled(true);
    let traced = run_zoo_search(ModelKind::Attention, &mesh, true);
    toast::obs::set_enabled(false);
    toast::obs::drain_chrome_trace(); // leave the global ring empty
    let tr = traced.trace.clone().expect("traced sessions attach telemetry");

    assert_eq!(
        canonical(plain),
        canonical(traced.clone()),
        "tracing changed the solution — it must be a pure observer"
    );
    // The telemetry itself is self-consistent: a monotone non-increasing
    // improvement curve ending at exactly the reported relative cost.
    assert!(!tr.curve.is_empty(), "a traced search records its curve");
    assert!(
        tr.curve.windows(2).all(|w| w[0].1 >= w[1].1),
        "curve must be monotone non-increasing: {:?}",
        tr.curve
    );
    assert_eq!(tr.curve.last().map(|&(_, c)| c), Some(traced.relative));
    assert!(!tr.phase_us.is_empty(), "a traced search records its phase breakdown");
}

/// A traced zoo search with the ring enabled emits a Chrome trace-event
/// document (the `toast trace` path): nonempty, round-trips through
/// `util/json.rs`, and every event carries the required fields.
#[test]
fn traced_zoo_search_emits_chrome_trace_json_that_roundtrips() {
    let _g = obs_guard();
    toast::obs::drain_chrome_trace(); // start from an empty ring
    toast::obs::set_enabled(true);
    let mesh = Mesh::grid(&[("data", 2), ("model", 2)]);
    let sol = run_zoo_search(ModelKind::Mlp, &mesh, true);
    toast::obs::set_enabled(false);
    assert!(sol.trace.is_some());

    let doc = toast::obs::drain_chrome_trace();
    let text = doc.render();
    let back = Json::parse(&text).expect("chrome trace re-parses");
    assert_eq!(back, doc, "render/parse must round-trip the document");

    let events = back
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("chrome trace document has a traceEvents array");
    assert!(!events.is_empty(), "a traced search must emit events");
    for ev in events {
        for field in ["name", "cat", "ph", "ts", "pid", "tid"] {
            assert!(ev.get(field).is_some(), "event missing '{field}': {}", ev.render());
        }
    }
    // The search hot path is represented: at least one search-category
    // span made it into the ring.
    assert!(
        events.iter().any(|e| e.get("cat").and_then(Json::as_str) == Some("search")),
        "expected search-category events in the trace"
    );
}
