//! MoE-subsystem acceptance tests (ISSUE 8):
//!
//! * **Routed reshards** — on meshes with a dedicated expert axis, the
//!   NDA-derived expert shardings partition with `all_to_all` reshards
//!   at dispatch and combine, and every such plan matches the
//!   interpreter oracle within 1e-4 relative tolerance on 1-D and 2-D
//!   meshes.
//! * **Search** — the flat MCTS's winning spec shards the expert
//!   dimension on the expert axis (tokens stay with their expert's
//!   devices; the `all_to_all`s are cheaper than gathering weights).
//! * **Pricing** — symbolic and incremental prices of routed plans pin
//!   to the materialize-and-evaluate oracle within 1e-6.
//! * **Composition** — on a memory-constrained config, the joint
//!   (stages × sharding) MCTS finds an (experts-in-stage ×
//!   pipeline-stages) plan that beats both the best flat expert plan
//!   and the best pipeline-only plan.

use toast::cost::symbolic::SymbolicEvaluator;
use toast::cost::CostModel;
use toast::ir::{Func, ValueId};
use toast::mesh::{HardwareKind, Mesh, Topology};
use toast::models::moe::{forward, MoeConfig};
use toast::nda::Nda;
use toast::pipeline::{joint_search, JointSearchConfig};
use toast::runtime::diff::{differential_test, DEFAULT_REL_TOL};
use toast::search::{build_actions, build_stage_actions, search, Action, ActionSpaceConfig,
    SearchConfig, StageActionConfig};
use toast::sharding::{partition, ShardingSpec};

fn tiny_forward() -> Func {
    let cfg = MoeConfig { training: false, ..MoeConfig::tiny() };
    forward(&cfg).0
}

/// Layer-0 expert FFN weight — its dim 0 is the expert dim. Params are
/// laid out x, then (wg, w1, w2, route) per layer.
fn w1_of(func: &Func) -> ValueId {
    ValueId(func.params.iter().position(|p| p.name == "l0_w1").unwrap() as u32)
}

fn actions_for(func: &Func, nda: &Nda, mesh: &Mesh) -> Vec<Action> {
    build_actions(func, nda, mesh, &ActionSpaceConfig { min_color_dims: 1, ..Default::default() })
}

/// Expert-dim resolutions of the merged routing color on `axis`.
fn expert_actions<'a>(actions: &'a [Action], w1: ValueId, axis: usize) -> Vec<&'a Action> {
    actions.iter().filter(|a| a.axis == axis && a.assignment.contains(&(w1, 0))).collect()
}

/// Acceptance: expert shardings exist, partition with routed
/// `all_to_all` reshards at dispatch and combine (≥ 2 per plan in the
/// aligned resolution), and every one differentially validates on both
/// a 1-D expert mesh and a 2-D expert × data mesh.
#[test]
fn expert_sharding_emits_routed_all_to_all_and_validates() {
    let func = tiny_forward();
    let nda = Nda::analyze(&func);
    let w1 = w1_of(&func);
    for mesh in [Mesh::grid(&[("expert", 2)]), Mesh::grid(&[("expert", 2), ("data", 2)])] {
        let actions = actions_for(&func, &nda, &mesh);
        let experts = expert_actions(&actions, w1, 0);
        assert!(
            !experts.is_empty(),
            "{}: the NDA must derive an expert-dim sharding action",
            mesh.describe()
        );
        let mut max_a2a = 0usize;
        for (ai, a) in experts.iter().enumerate() {
            let mut spec = ShardingSpec::unsharded(&func);
            assert!(
                spec.check_assignment(&func, &mesh, &a.assignment, a.axis),
                "{} action {ai}: assignment must be legal",
                mesh.describe()
            );
            spec.apply_assignment(&func, &mesh, &a.assignment, a.axis).unwrap();
            let (_, stats) = partition(&func, &spec, &mesh).unwrap_or_else(|e| {
                panic!("{} action {ai}: partition failed: {e:#}", mesh.describe())
            });
            max_a2a = max_a2a.max(stats.all_to_all);
            let r = differential_test(&func, &spec, &mesh, 29).unwrap_or_else(|e| {
                panic!("{} action {ai}: differential failed: {e:#}", mesh.describe())
            });
            assert!(
                r.within(DEFAULT_REL_TOL),
                "{} action {ai}: rel {} (collectives {})",
                mesh.describe(),
                r.max_rel_err,
                r.stats.total_collectives()
            );
        }
        // The aligned resolution reshards the routed tensors at dispatch
        // AND combine — at least two all_to_alls (tiny has 2 layers, so
        // the aligned plan carries more; ≥ 2 is the structural floor).
        assert!(
            max_a2a >= 2,
            "{}: expected routed all_to_all at dispatch and combine, best plan had {max_a2a}",
            mesh.describe()
        );
    }
}

/// Acceptance: the flat search's winning spec shards the expert dim on
/// the dedicated expert axis, and the winner validates differentially.
#[test]
fn flat_search_shards_the_expert_dimension() {
    let func = tiny_forward();
    let nda = Nda::analyze(&func);
    let w1 = w1_of(&func);
    let model = CostModel::new(Topology::from_kind(HardwareKind::A100));
    for mesh in [Mesh::grid(&[("expert", 2)]), Mesh::grid(&[("expert", 2), ("data", 2)])] {
        let actions = actions_for(&func, &nda, &mesh);
        let out = search(
            &func,
            &mesh,
            &model,
            &actions,
            &SearchConfig { budget: 300, threads: 1, seed: 7, ..Default::default() },
        );
        assert!(out.relative < 1.0, "{}: search must improve on replicated", mesh.describe());
        assert!(
            !out.spec.dims[w1.0 as usize][0].is_empty(),
            "{}: winning spec must shard the expert dim of w1 (spec relative {})",
            mesh.describe(),
            out.relative
        );
        let (_, stats) = partition(&func, &out.spec, &mesh).unwrap();
        assert!(
            stats.all_to_all >= 2,
            "{}: winning plan must route tokens (all_to_all {})",
            mesh.describe(),
            stats.all_to_all
        );
        let r = differential_test(&func, &out.spec, &mesh, 31).unwrap();
        assert!(r.within(DEFAULT_REL_TOL), "{}: rel {}", mesh.describe(), r.max_rel_err);
    }
}

/// Acceptance: symbolic pricing of routed plans pins to the
/// materialize-and-evaluate oracle within 1e-6 relative.
#[test]
fn routed_plans_price_to_the_oracle() {
    let func = tiny_forward();
    let nda = Nda::analyze(&func);
    let w1 = w1_of(&func);
    let model = CostModel::new(Topology::from_kind(HardwareKind::A100));
    for mesh in [Mesh::grid(&[("expert", 2)]), Mesh::grid(&[("expert", 2), ("data", 2)])] {
        let actions = actions_for(&func, &nda, &mesh);
        let sym = SymbolicEvaluator::new(&func, &mesh, &model);
        let (ulocal, _) = partition(&func, &ShardingSpec::unsharded(&func), &mesh).unwrap();
        let base = model.evaluate(&ulocal, &mesh);
        for a in expert_actions(&actions, w1, 0) {
            let mut spec = ShardingSpec::unsharded(&func);
            spec.apply_assignment(&func, &mesh, &a.assignment, a.axis).unwrap();
            let (local, _) = partition(&func, &spec, &mesh).unwrap();
            let oracle = model.relative(&model.evaluate(&local, &mesh), &base);
            let s = sym.relative(&spec, &base);
            assert!(
                (s - oracle).abs() <= 1e-6 * oracle.max(1.0),
                "{}: symbolic {s} vs oracle {oracle}",
                mesh.describe()
            );
        }
    }
}

/// Acceptance: on a memory-constrained config, the joint MCTS finds an
/// (experts-in-stage × pipeline-stages) composition that beats both the
/// best flat (expert-only) plan and the best pipeline-only plan.
#[test]
fn joint_search_composes_experts_with_stages() {
    let cfg = MoeConfig { layers: 6, training: false, ..MoeConfig::tiny() };
    let (func, _, _) = forward(&cfg);
    let nda = Nda::analyze(&func);
    let intra = Mesh::grid(&[("expert", 2)]);
    let mut model = CostModel::new(Topology::from_kind(HardwareKind::A100));
    let actions = actions_for(&func, &nda, &intra);
    let stage_actions = build_stage_actions(
        &func,
        &nda,
        &StageActionConfig { counts: vec![2, 4], microbatches: 8, ..Default::default() },
    );
    assert!(!stage_actions.is_empty(), "MoE layers must offer legal stage cuts");

    // Constrain memory so no flat plan fits: one mesh axis at best
    // halves the weights, so 40% of the unsharded peak is out of reach
    // flat, while stages divide the weights further.
    let (ulocal, _) = partition(&func, &ShardingSpec::unsharded(&func), &intra).unwrap();
    let base = model.evaluate(&ulocal, &intra);
    model.hw.device.memory_bytes = base.peak_bytes * 2 / 5;

    let flat = search(
        &func,
        &intra,
        &model,
        &actions,
        &SearchConfig { budget: 300, threads: 1, seed: 5, ..Default::default() },
    );
    assert!(
        !model.fits(&flat.cost),
        "flat expert-only search must OOM here (peak {}, limit {})",
        flat.cost.peak_bytes,
        model.hw.device.memory_bytes
    );

    // Pipeline-only comparator: stages without any sharding actions.
    let pipe_only = joint_search(
        &func,
        &intra,
        &model,
        &[],
        &stage_actions,
        &JointSearchConfig { budget: 300, seed: 5, require_stage: true, ..Default::default() },
    )
    .unwrap();
    assert!(pipe_only.stage_action.is_some());

    let joint = joint_search(
        &func,
        &intra,
        &model,
        &actions,
        &stage_actions,
        &JointSearchConfig { budget: 400, seed: 5, require_stage: true, ..Default::default() },
    )
    .unwrap();
    assert!(joint.stage_action.is_some(), "joint search must stage the model");
    assert!(
        joint.spec.sharded_dim_count() > 0 && !joint.actions.is_empty(),
        "joint search must shard inside the stage"
    );
    assert!(!joint.oom, "the composition must fit (peak {})", joint.cost.peak_bytes);
    assert!(
        joint.relative < flat.relative,
        "composition ({}) must beat the memory-penalized flat expert plan ({})",
        joint.relative,
        flat.relative
    );
    assert!(
        joint.relative < pipe_only.relative,
        "composition ({}) must beat the pipeline-only plan ({})",
        joint.relative,
        pipe_only.relative
    );
}
