//! Pipeline-subsystem tests:
//!
//! * **P11 (cut soundness)** — every NDA-enumerated cut of a model
//!   yields stages whose sequential composition is interp-equivalent to
//!   the original function (bit-identical: same instructions, same
//!   order, same kernel), over zoo models and random programs.
//! * **Staged differential** — `StagedModule`s for the mlp and the
//!   (scaled) transformer at 2 and 4 stages execute end to end on the
//!   extended SPMD simulator and match the interpreter oracle within
//!   1e-4 relative tolerance, including under sharding.
//! * **Schedule pricing** — the symbolic schedule price agrees with the
//!   simulate-then-price oracle to ≤ 1e-6 relative.
//! * **OOM → feasible** — on a memory-constrained configuration where
//!   the pure SPMD search reports `oom=true`, the joint
//!   (stages × sharding) MCTS finds a feasible solution.

use toast::cost::CostModel;
use toast::ir::interp::eval_func;
use toast::ir::{Func, FuncBuilder, ReduceKind, TensorType, UnaryOp, ValueId};
use toast::mesh::{HardwareKind, Mesh, Topology};
use toast::models::ModelKind;
use toast::nda::Nda;
use toast::pipeline::{
    balanced_boundaries, compute_weight, cut_stages, eval_staged_interp, legal_boundaries,
    run_staged, schedule,
};
use toast::runtime::diff::{differential_test_staged, random_inputs, DEFAULT_REL_TOL};
use toast::search::{build_actions, build_stage_actions, ActionSpaceConfig, StageActionConfig};
use toast::sharding::{partition, ShardingSpec};
use toast::util::Rng;

/// Random straight-line program generator (a compact sibling of the one
/// in `property.rs`, biased toward chains so cuts exist).
fn random_func(rng: &mut Rng) -> Func {
    let dims = [2i64, 4, 8];
    let mut b = FuncBuilder::new("pipe_prop");
    let rank = 2usize;
    let shape: Vec<i64> = (0..rank).map(|_| dims[rng.below(dims.len())]).collect();
    let mut values: Vec<(ValueId, Vec<i64>)> = Vec::new();
    let x = b.param("p0", TensorType::f32(shape.clone()));
    values.push((x, shape));
    let n_ops = 4 + rng.below(8);
    for _ in 0..n_ops {
        let pick = rng.below(values.len());
        let (x, xs) = values[pick].clone();
        match rng.below(5) {
            0 => {
                let v = b.relu(x);
                values.push((v, xs));
            }
            1 => {
                let partner: Vec<ValueId> = values
                    .iter()
                    .filter(|(_, s)| *s == xs)
                    .map(|(v, _)| *v)
                    .collect();
                let y = partner[rng.below(partner.len())];
                let v = b.add(x, y);
                values.push((v, xs));
            }
            2 if xs.len() == 2 => {
                let k = xs[1];
                let n = dims[rng.below(dims.len())];
                let w = b.constant(0.1, TensorType::f32(vec![k, n]));
                let v = b.dot_general(x, w, &[], &[], &[1], &[0]);
                values.push((v, vec![xs[0], n]));
            }
            3 if xs.len() == 2 => {
                let d = rng.below(2);
                let v = b.reduce(x, &[d], ReduceKind::Add);
                let shape: Vec<i64> =
                    xs.iter().enumerate().filter(|(i, _)| *i != d).map(|(_, &s)| s).collect();
                values.push((v, shape));
            }
            _ => {
                let v = b.unary(UnaryOp::Tanh, x);
                values.push((v, xs));
            }
        }
    }
    let last = values.last().unwrap().0;
    b.build(vec![last])
}

/// P11: every enumerated single cut — and a balanced multi-cut — of a
/// function composes back to the original semantics, bit for bit.
#[test]
fn prop_every_cut_composes_to_the_original_p11() {
    // Zoo models small enough to sweep every boundary.
    for kind in [ModelKind::Mlp, ModelKind::Attention] {
        let func = kind.build_scaled();
        assert_cuts_compose(&func, &format!("zoo {}", kind.name()));
    }
    // Random straight-line programs.
    let mut rng = Rng::new(0x9199);
    for case in 0..25 {
        let func = random_func(&mut rng);
        toast::ir::verifier::verify_logical(&func)
            .unwrap_or_else(|e| panic!("case {case}: invalid func: {e:#}"));
        assert_cuts_compose(&func, &format!("random case {case}"));
    }
}

fn assert_cuts_compose(func: &Func, label: &str) {
    let nda = Nda::analyze(func);
    let legal = legal_boundaries(func, &nda);
    let inputs = random_inputs(func, 0xA11CE);
    let expected = eval_func(func, &inputs).unwrap();
    for &b in &legal {
        let sm = cut_stages(func, &[b]).unwrap();
        let got = eval_staged_interp(&sm, &inputs)
            .unwrap_or_else(|e| panic!("{label}: boundary {b}: {e:#}"));
        for (ri, (e, g)) in expected.iter().zip(&got).enumerate() {
            assert_eq!(
                e.data, g.data,
                "{label}: boundary {b} changed result {ri} (composition must be exact)"
            );
        }
    }
    // One balanced multi-cut (when supported) composes too.
    for k in [3usize, 4] {
        if let Some(bounds) = balanced_boundaries(func, &legal, k, compute_weight) {
            let sm = cut_stages(func, &bounds).unwrap();
            let got = eval_staged_interp(&sm, &inputs).unwrap();
            for (e, g) in expected.iter().zip(&got) {
                assert_eq!(e.data, g.data, "{label}: {k}-stage cut {bounds:?} diverged");
            }
        }
    }
}

/// Acceptance: mlp and transformer staged at 2 and 4 stages execute on
/// the extended SPMD simulator and pass differential validation against
/// the interpreter oracle (1e-4 relative tolerance), replicated and
/// sharded.
#[test]
fn staged_mlp_and_transformer_match_the_oracle_at_2_and_4_stages() {
    for kind in [ModelKind::Mlp, ModelKind::T2B] {
        let func = kind.build_scaled();
        let nda = Nda::analyze(&func);
        let legal = legal_boundaries(&func, &nda);
        for k in [2usize, 4] {
            let bounds = balanced_boundaries(&func, &legal, k, compute_weight)
                .unwrap_or_else(|| panic!("{}: no {k}-stage cut", kind.name()));
            let intra = Mesh::grid(&[("d", 2)]);
            for (label, spec) in
                [("unsharded", ShardingSpec::unsharded(&func)), ("sharded", walk_spec(&func, &nda, &intra))]
            {
                let r = differential_test_staged(&func, &spec, &bounds, &intra, 21).unwrap();
                assert!(
                    r.within(DEFAULT_REL_TOL),
                    "{} k={k} {label}: rel {}",
                    kind.name(),
                    r.max_rel_err
                );
            }
        }
    }
}

/// A partitioner-realistic sharded spec: greedy walk over the NDA action
/// space (the experiments' generator, inlined to stay independent).
fn walk_spec(func: &Func, nda: &Nda, mesh: &Mesh) -> ShardingSpec {
    let actions = build_actions(
        func,
        nda,
        mesh,
        &ActionSpaceConfig { min_color_dims: 1, ..Default::default() },
    );
    let mut spec = ShardingSpec::unsharded(func);
    let mut applied = 0usize;
    for a in &actions {
        if applied >= 3 {
            break;
        }
        if spec.check_assignment(func, mesh, &a.assignment, a.axis)
            && spec.apply_assignment(func, mesh, &a.assignment, a.axis).is_ok()
        {
            applied += 1;
        }
    }
    spec
}

/// Acceptance: schedule-cost pricing of a staged spec agrees with the
/// simulate-then-price oracle to ≤ 1e-6 relative.
#[test]
fn schedule_pricing_agrees_with_the_oracle_on_zoo_models() {
    let model = CostModel::new(Topology::from_kind(HardwareKind::A100));
    for kind in [ModelKind::Mlp, ModelKind::T2B] {
        let func = kind.build_scaled();
        let nda = Nda::analyze(&func);
        let legal = legal_boundaries(&func, &nda);
        for k in [2usize, 4] {
            let Some(bounds) = balanced_boundaries(&func, &legal, k, compute_weight) else {
                panic!("{}: no {k}-stage cut", kind.name());
            };
            let sm = cut_stages(&func, &bounds).unwrap();
            let intra = Mesh::grid(&[("a", 2), ("b", 2)]);
            for spec in [ShardingSpec::unsharded(&func), walk_spec(&func, &nda, &intra)] {
                let sym = schedule::price_staged_symbolic(&sm, &spec, &intra, &model, 8).unwrap();
                let orc = schedule::price_staged_oracle(&sm, &spec, &intra, &model, 8).unwrap();
                let gap = (sym.cost.runtime_s - orc.cost.runtime_s).abs()
                    / orc.cost.runtime_s.abs().max(1e-30);
                assert!(
                    gap <= 1e-6,
                    "{} k={k}: symbolic {} vs oracle {} (gap {gap:.3e})",
                    kind.name(),
                    sym.cost.runtime_s,
                    orc.cost.runtime_s
                );
                assert_eq!(sym.cost.peak_bytes, orc.cost.peak_bytes);
            }
        }
    }
}

fn deep_chain(layers: usize, batch: i64, d: i64) -> Func {
    let mut b = FuncBuilder::new("deep");
    let mut x = b.param("x", TensorType::f32(vec![batch, d]));
    for l in 0..layers {
        let w = b.param(format!("w{l}"), TensorType::f32(vec![d, d]));
        let y = b.matmul(x, w);
        x = b.relu(y);
    }
    b.build(vec![x])
}

/// Acceptance: on a memory-constrained config where pure SPMD search
/// reports `oom=true`, the MCTS with stage actions finds a feasible
/// (`oom=false`) solution.
///
/// The model is sized so per-stage compute dominates the stage-axis hop
/// latency (the regime pipelining targets) — pricing only, nothing is
/// executed numerically at this size; the numeric soundness of staged
/// execution is covered by the differential tests above on
/// interpreter-sized models.
#[test]
fn stage_actions_turn_oom_into_feasible() {
    let func = deep_chain(10, 512, 2048);
    let intra = Mesh::grid(&[("d", 2)]);
    let mut model = CostModel::new(Topology::from_kind(HardwareKind::A100));
    let nda = Nda::analyze(&func);
    let actions = build_actions(
        &func,
        &nda,
        &intra,
        &ActionSpaceConfig { min_color_dims: 1, ..Default::default() },
    );
    let stage_actions = build_stage_actions(
        &func,
        &nda,
        &StageActionConfig { counts: vec![2, 4], microbatches: 8, ..Default::default() },
    );
    assert!(stage_actions.iter().any(|a| a.stages == 4));

    // Constrain memory to 40% of the unstaged unsharded peak: below the
    // sharded parameter floor (one mesh axis halves the weights at
    // best), so every flat state OOMs — while a 4-stage cut holds 2-3
    // of the 10 layers per stage and fits.
    let (ulocal, _) = partition(&func, &ShardingSpec::unsharded(&func), &intra).unwrap();
    let base = model.evaluate(&ulocal, &intra);
    model.hw.device.memory_bytes = base.peak_bytes * 2 / 5;

    let flat = toast::search::search(
        &func,
        &intra,
        &model,
        &actions,
        &toast::search::SearchConfig { budget: 200, threads: 1, seed: 3, ..Default::default() },
    );
    assert!(
        !model.fits(&flat.cost),
        "pure SPMD search must report OOM here (peak {}, limit {})",
        flat.cost.peak_bytes,
        model.hw.device.memory_bytes
    );

    let joint = toast::pipeline::joint_search(
        &func,
        &intra,
        &model,
        &actions,
        &stage_actions,
        &toast::pipeline::JointSearchConfig { budget: 300, seed: 3, ..Default::default() },
    )
    .unwrap();
    assert!(joint.stage_action.is_some(), "the joint search must pick a stage action");
    assert!(
        !joint.oom,
        "staged solution must fit (peak {}, limit {})",
        joint.cost.peak_bytes,
        model.hw.device.memory_bytes
    );
    assert!(
        joint.relative < flat.relative,
        "staged ({}) must beat the memory-penalized flat solution ({})",
        joint.relative,
        flat.relative
    );
}

/// The staged executor moves transfers point-to-point: carries hop every
/// boundary and sharded transfer tensors arrive intact on 2-D intra
/// meshes.
#[test]
fn staged_execution_on_a_2d_intra_mesh() {
    let func = deep_chain(4, 16, 64);
    let nda = Nda::analyze(&func);
    let legal = legal_boundaries(&func, &nda);
    let bounds = balanced_boundaries(&func, &legal, 4, compute_weight).unwrap();
    let sm = cut_stages(&func, &bounds).unwrap();
    let intra = Mesh::grid(&[("a", 2), ("b", 2)]);
    let spec = walk_spec(&func, &nda, &intra);
    let inputs = random_inputs(&func, 33);
    let expected = eval_func(&func, &inputs).unwrap();
    let (got, stats) = run_staged(&sm, &spec, &intra, &inputs).unwrap();
    for (e, g) in expected.iter().zip(&got) {
        assert!(e.max_rel_err(g) < 1e-4, "rel {}", e.max_rel_err(g));
    }
    // sanity: the stats aggregate over stages (collectives may be zero
    // for batch-style shardings; shard_slices usually are not)
    let _ = stats.total_collectives();
}
