//! Property-based tests (in-tree harness — proptest is unavailable in the
//! offline registry; DESIGN.md documents the substitution).
//!
//! A generator produces random straight-line tensor programs and random
//! legal sharding specs; the properties assert the system's core
//! invariants over hundreds of (program, spec) samples:
//!
//! * **P1 (soundness)**: partition(f, spec) executed on the lock-step
//!   SPMD interpreter matches f's unpartitioned execution;
//! * **P2**: NDA colors are size-uniform; conflicts pair same-colored
//!   dims;
//! * **P3**: every NDA sharding assignment shards at most one dim per
//!   value, and applying it succeeds when sizes divide;
//! * **P4**: the canonical search state is order-independent;
//! * **P5**: the cost model is invariant under identity partitioning and
//!   penalizes memory overflow;
//! * **P9**: the SPMD simulation runtime matches the interpreter oracle
//!   for random (program, spec, mesh) triples within 1e-4 relative
//!   tolerance, with shrink-and-report on failure;
//! * **P11**: the routed-dispatch rule derives sound expert shardings
//!   (routed `all_to_all`) for random MoE configurations;
//! * **P10 (wire)**: specs, meshes, stage assignments, custom
//!   topologies and whole solution artifacts round-trip through JSON to
//!   equal values that price bit-identically;
//! * **P12**: with all link tiers equal, hierarchical topology pricing
//!   is flat — blind to which same-size mesh axis carries a sharding.

use toast::cost::symbolic::SymbolicEvaluator;
use toast::cost::CostModel;
use toast::ir::interp::Tensor;
use toast::ir::{DType, Func, FuncBuilder, ReduceKind, TensorType, ValueId};
use toast::mesh::{HardwareKind, Mesh, Topology};
use toast::models::ModelKind;
use toast::nda::Nda;
use toast::search::IncrementalEvaluator;
use toast::sharding::{partition, validate_spec, ShardingSpec};
use toast::util::Rng;

/// Random straight-line program generator. Sizes are products of small
/// powers of two so random shardings are frequently legal.
fn random_func(rng: &mut Rng) -> Func {
    let dims = [2i64, 4, 8, 16];
    let mut b = FuncBuilder::new("prop");
    let n_params = 2 + rng.below(3);
    let mut values: Vec<(ValueId, Vec<i64>)> = Vec::new();
    for p in 0..n_params {
        let rank = 1 + rng.below(3);
        let shape: Vec<i64> = (0..rank).map(|_| dims[rng.below(dims.len())]).collect();
        let v = b.param(format!("p{p}"), TensorType::f32(shape.clone()));
        values.push((v, shape));
    }
    let n_ops = 3 + rng.below(10);
    for _ in 0..n_ops {
        let pick = rng.below(values.len());
        let (x, xs) = values[pick].clone();
        match rng.below(7) {
            0 => {
                // unary
                let v = b.relu(x);
                values.push((v, xs));
            }
            1 => {
                // binary with a same-shaped partner (generate via relu if none)
                let partner = values
                    .iter()
                    .filter(|(_, s)| *s == xs)
                    .map(|(v, _)| *v)
                    .collect::<Vec<_>>();
                let y = partner[rng.below(partner.len())];
                let v = b.add(x, y);
                values.push((v, xs));
            }
            2 if xs.len() >= 2 => {
                // transpose
                let mut perm: Vec<usize> = (0..xs.len()).collect();
                rng.shuffle(&mut perm);
                let v = b.transpose(x, &perm);
                let shape = perm.iter().map(|&p| xs[p]).collect();
                values.push((v, shape));
            }
            3 if xs.len() >= 2 => {
                // matmul with a fresh weight
                let k = *xs.last().unwrap();
                let n = dims[rng.below(dims.len())];
                let w = b.constant(0.1, TensorType::f32(vec![k, n]));
                let lc = xs.len() - 1;
                let v = b.dot_general(x, w, &[], &[], &[lc], &[0]);
                let mut shape = xs[..lc].to_vec();
                shape.push(n);
                values.push((v, shape));
            }
            4 if xs.len() >= 2 => {
                // reduce one dim
                let d = rng.below(xs.len());
                let v = b.reduce(x, &[d], ReduceKind::Add);
                let shape: Vec<i64> = xs
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| *i != d)
                    .map(|(_, &s)| s)
                    .collect();
                values.push((v, shape));
            }
            5 => {
                // broadcast a new leading dim
                let nd = dims[rng.below(dims.len())];
                let mut shape = vec![nd];
                shape.extend(&xs);
                let bc_dims: Vec<usize> = (1..=xs.len()).collect();
                let v = b.broadcast(x, &shape, &bc_dims);
                values.push((v, shape));
            }
            _ => {
                let v = b.unary(toast::ir::UnaryOp::Tanh, x);
                values.push((v, xs));
            }
        }
    }
    let last = values.last().unwrap().0;
    b.build(vec![last])
}

/// A random legal spec — the shared generator in `runtime::diff`, so the
/// property suite and the experiment sweep can never silently diverge.
fn random_spec(func: &Func, mesh: &Mesh, rng: &mut Rng) -> ShardingSpec {
    toast::runtime::diff::random_legal_spec(func, mesh, rng)
}

/// P1: the partitioner is semantics-preserving for arbitrary programs and
/// arbitrary legal specs.
#[test]
fn prop_partition_preserves_semantics() {
    let mut rng = Rng::new(0xF00D);
    let mesh = Mesh::grid(&[("a", 2), ("b", 2)]);
    let mut checked = 0;
    for case in 0..120 {
        let func = random_func(&mut rng);
        toast::ir::verifier::verify_logical(&func)
            .unwrap_or_else(|e| panic!("case {case} generated invalid func: {e:#}"));
        let spec = random_spec(&func, &mesh, &mut rng);
        let v = validate_spec(&func, &spec, &mesh, case as u64)
            .unwrap_or_else(|e| panic!("case {case}: {e:#}\n{func}"));
        assert!(
            v.max_abs_diff < 1e-2,
            "case {case}: diff {} \n{func}",
            v.max_abs_diff
        );
        checked += 1;
    }
    assert_eq!(checked, 120);
}

/// P2: NDA invariants — colors are size-uniform and conflicts pair dims
/// of the same color.
#[test]
fn prop_nda_invariants() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..150 {
        let func = random_func(&mut rng);
        let nda = Nda::analyze(&func);
        // colors partition all def dims and agree on sizes
        let mut seen = 0;
        for (c, info) in nda.colors.iter().enumerate() {
            for &(v, d) in &info.members {
                assert_eq!(nda.color_of(v, d), c);
                assert_eq!(func.ty(v).shape[d], info.dim_size, "color {c} size mismatch");
                seen += 1;
            }
        }
        let total_dims: usize =
            (0..func.num_values()).map(|v| func.ty(ValueId(v as u32)).rank()).sum();
        assert_eq!(seen, total_dims, "colors must partition all dims");
        // conflicts pair same-colored, distinct I-classes
        for cf in &nda.conflicts.conflicts {
            assert_ne!(cf.class_a, cf.class_b);
            assert_eq!(
                nda.color[cf.class_a as usize], nda.color[cf.class_b as usize],
                "conflict endpoints must share a color"
            );
            assert!(!cf.occurrences.is_empty());
        }
        // every conflict belongs to exactly one compatibility set
        let mut counted = 0;
        for set in &nda.conflicts.compat_sets {
            counted += set.len();
        }
        assert_eq!(counted, nda.conflicts.conflicts.len());
    }
}

/// P3: sharding assignments are per-value unique and applicable.
#[test]
fn prop_assignments_unique_and_applicable() {
    let mut rng = Rng::new(0xCAFE);
    let mesh = Mesh::grid(&[("a", 2)]);
    for _ in 0..100 {
        let func = random_func(&mut rng);
        let nda = Nda::analyze(&func);
        for color in nda.significant_colors(1) {
            let assign = nda.sharding_assignment(color, 0);
            let mut values: Vec<ValueId> = assign.iter().map(|&(v, _)| v).collect();
            values.sort_unstable();
            let before = values.len();
            values.dedup();
            assert_eq!(before, values.len(), "assignment must shard each value once");
            // apply if every member divides
            if assign
                .iter()
                .all(|&(v, d)| func.ty(v).shape[d] % mesh.axis_size(0) as i64 == 0)
            {
                let mut spec = ShardingSpec::unsharded(&func);
                spec.apply_assignment(&func, &mesh, &assign, 0).unwrap();
            }
        }
    }
}

/// P4: the search's canonical state is order-independent — applying the
/// same action set in different orders yields identical specs.
#[test]
fn prop_action_order_irrelevant() {
    let mut rng = Rng::new(0xD00D);
    let mesh = Mesh::grid(&[("a", 2), ("b", 2)]);
    for _ in 0..60 {
        let func = random_func(&mut rng);
        let nda = Nda::analyze(&func);
        let actions = toast::search::build_actions(
            &func,
            &nda,
            &mesh,
            &toast::search::ActionSpaceConfig { min_color_dims: 1, ..Default::default() },
        );
        if actions.len() < 2 {
            continue;
        }
        let i = rng.below(actions.len());
        let mut j = rng.below(actions.len());
        if i == j {
            j = (j + 1) % actions.len();
        }
        let apply = |order: [usize; 2]| -> Option<ShardingSpec> {
            let mut spec = ShardingSpec::unsharded(&func);
            for &k in &order {
                let a = &actions[k];
                spec.apply_assignment(&func, &mesh, &a.assignment, a.axis).ok()?;
            }
            Some(spec)
        };
        if let (Some(s1), Some(s2)) = (apply([i, j]), apply([j, i])) {
            // multi-axis stacking on one dim may record axes in
            // application order; compare as sets per dim
            for (d1, d2) in s1.dims.iter().zip(&s2.dims) {
                for (a1, a2) in d1.iter().zip(d2) {
                    let mut x = a1.clone();
                    let mut y = a2.clone();
                    x.sort_unstable();
                    y.sort_unstable();
                    assert_eq!(x, y, "specs must agree regardless of action order");
                }
            }
        }
    }
}

/// P5: cost-model sanity over random programs.
#[test]
fn prop_cost_model_sane() {
    let mut rng = Rng::new(0xABBA);
    let mesh = Mesh::grid(&[("a", 2), ("b", 2)]);
    let model = toast::cost::CostModel::new(Topology::from_kind(HardwareKind::TPUv3));
    for _ in 0..80 {
        let func = random_func(&mut rng);
        let spec = ShardingSpec::unsharded(&func);
        let (local, stats) = partition(&func, &spec, &mesh).unwrap();
        assert_eq!(stats.total_collectives(), 0);
        let c = model.evaluate(&local, &mesh);
        assert!(c.runtime_s > 0.0 && c.runtime_s.is_finite());
        assert!(c.peak_bytes >= func.param_bytes());
        assert_eq!(model.relative(&c, &c), 1.0);
        // a sharded variant never increases peak memory per device
        let rspec = random_spec(&func, &mesh, &mut rng);
        if let Ok((rlocal, _)) = partition(&func, &rspec, &mesh) {
            let rc = model.evaluate(&rlocal, &mesh);
            assert!(rc.runtime_s.is_finite());
        }
    }
}

/// Oracle relative cost of `spec` (`+inf` when partitioning fails).
fn oracle_relative(
    func: &Func,
    spec: &ShardingSpec,
    mesh: &Mesh,
    model: &CostModel,
    base: &toast::cost::Cost,
) -> f64 {
    match partition(func, spec, mesh) {
        Ok((local, _)) => model.relative(&model.evaluate(&local, mesh), base),
        Err(_) => f64::INFINITY,
    }
}

fn oracle_base(func: &Func, mesh: &Mesh, model: &CostModel) -> toast::cost::Cost {
    let unsharded = ShardingSpec::unsharded(func);
    let (local, _) = partition(func, &unsharded, mesh).unwrap();
    model.evaluate(&local, mesh)
}

/// P7: the symbolic cost evaluator agrees with the
/// materialize-partition-evaluate oracle within 1e-6 relative cost across
/// random specs on the zoo models (MLP / Transformer / U-Net) and random
/// programs.
#[test]
fn prop_symbolic_cost_matches_materialized() {
    let mut rng = Rng::new(0x70A57);
    let mesh = Mesh::grid(&[("a", 2), ("b", 2)]);
    let model = CostModel::new(Topology::from_kind(HardwareKind::A100));
    for kind in [ModelKind::Mlp, ModelKind::T2B, ModelKind::UNet] {
        let func = kind.build_scaled();
        let base = oracle_base(&func, &mesh, &model);
        let sym = SymbolicEvaluator::new(&func, &mesh, &model);
        for case in 0..25 {
            let spec = random_spec(&func, &mesh, &mut rng);
            let oracle = oracle_relative(&func, &spec, &mesh, &model, &base);
            let s = sym.relative(&spec, &base);
            if oracle.is_finite() {
                assert!(
                    (s - oracle).abs() <= 1e-6 * oracle.max(1.0),
                    "{} case {case}: symbolic {s} vs oracle {oracle}",
                    kind.name()
                );
            } else {
                assert!(s.is_infinite(), "{} case {case}: oracle failed, symbolic {s}", kind.name());
            }
        }
    }
    // ...and across random straight-line programs.
    for case in 0..60 {
        let func = random_func(&mut rng);
        let base = oracle_base(&func, &mesh, &model);
        let sym = SymbolicEvaluator::new(&func, &mesh, &model);
        let spec = random_spec(&func, &mesh, &mut rng);
        let oracle = oracle_relative(&func, &spec, &mesh, &model, &base);
        let s = sym.relative(&spec, &base);
        if oracle.is_finite() {
            assert!(
                (s - oracle).abs() <= 1e-6 * oracle.max(1.0),
                "random case {case}: symbolic {s} vs oracle {oracle}\n{func}"
            );
        } else {
            assert!(s.is_infinite(), "random case {case}: oracle failed, symbolic {s}");
        }
    }
}

/// P8: the incremental engine tracks the oracle through realistic action
/// walks (apply/undo on the real action space).
#[test]
fn prop_incremental_matches_oracle_on_action_walks() {
    let mut rng = Rng::new(0x17C4);
    let mesh = Mesh::grid(&[("a", 2), ("b", 2)]);
    let model = CostModel::new(Topology::from_kind(HardwareKind::A100));
    for kind in [ModelKind::Mlp, ModelKind::T2B, ModelKind::UNet] {
        let func = kind.build_scaled();
        let nda = Nda::analyze(&func);
        let actions = toast::search::build_actions(
            &func,
            &nda,
            &mesh,
            &toast::search::ActionSpaceConfig { min_color_dims: 1, ..Default::default() },
        );
        if actions.is_empty() {
            continue;
        }
        let base = oracle_base(&func, &mesh, &model);
        let mut eng = IncrementalEvaluator::new(&func, &mesh, &model, base).unwrap();
        for _walk in 0..4 {
            eng.reset();
            for _step in 0..4 {
                let a = &actions[rng.below(actions.len())];
                if eng.spec().check_assignment(&func, &mesh, &a.assignment, a.axis) {
                    eng.apply(&a.assignment, a.axis).unwrap();
                }
                let got = eng.relative();
                let oracle = oracle_relative(&func, eng.spec(), &mesh, &model, &base);
                if oracle.is_finite() {
                    assert!(
                        (got - oracle).abs() <= 1e-6 * oracle.max(1.0),
                        "{}: incremental {got} vs oracle {oracle}",
                        kind.name()
                    );
                } else {
                    assert!(got.is_infinite());
                }
            }
            // unwinding one step restores the previous state's cost
            if eng.depth() > 0 {
                eng.undo();
                let got = eng.relative();
                let oracle = oracle_relative(&func, eng.spec(), &mesh, &model, &base);
                if oracle.is_finite() {
                    assert!((got - oracle).abs() <= 1e-6 * oracle.max(1.0));
                }
            }
        }
    }
}

/// P9: the SPMD simulator matches the unsharded interpreter oracle for
/// random logical programs × random legal `ShardingSpec`s × random
/// meshes (1-D and 2-D, including singleton axes) within 1e-4 relative
/// tolerance. A failing case is shrunk to a minimal `(program, spec,
/// mesh)` triple and reported readably.
#[test]
fn prop_spmd_differential_p9() {
    use toast::runtime::diff::{differential_test, shrink_failure, DEFAULT_REL_TOL};
    let mut rng = Rng::new(0x5D9);
    // The sweep's shared mesh set (two 1-D, 2-D, singleton-axis 2-D),
    // plus a trailing-singleton variant only the property suite needs —
    // one source of truth with the experiments' differential suite.
    let mut meshes: Vec<Mesh> = toast::coordinator::experiments::differential_meshes();
    meshes.push(Mesh::grid(&[("a", 2), ("b", 1)]));
    let mut with_collectives = 0usize;
    for case in 0..80 {
        let mesh = &meshes[case % meshes.len()];
        let func = random_func(&mut rng);
        // A check-legal spec the partitioner rejects has nothing to
        // compare (the suite in coordinator::experiments retries the
        // same way) — resample a few times, falling back to replicated.
        let mut spec = ShardingSpec::unsharded(&func);
        for _attempt in 0..5 {
            let cand = random_spec(&func, mesh, &mut rng);
            if partition(&func, &cand, mesh).is_ok() {
                spec = cand;
                break;
            }
        }
        let seed = 0x900 + case as u64;
        let outcome = differential_test(&func, &spec, mesh, seed);
        let ok = match &outcome {
            Ok(r) => {
                if r.stats.total_collectives() > 0 {
                    with_collectives += 1;
                }
                r.within(DEFAULT_REL_TOL)
            }
            Err(_) => false,
        };
        if !ok {
            let shrunk = shrink_failure(&func, &spec, mesh, seed, DEFAULT_REL_TOL);
            panic!(
                "P9 case {case} failed on {}; minimized reproduction:\n{}",
                mesh.describe(),
                shrunk.report
            );
        }
    }
    // The sweep must actually exercise data movement, not just
    // replicated re-execution.
    assert!(with_collectives >= 5, "only {with_collectives} cases had collectives");
}

/// P11: the routed-dispatch NDA rule — for random expert counts,
/// capacities, and group sizes, the MoE dispatch pattern merges the
/// expert and group dims into one color, and every expert-sharding
/// action the space derives for it partitions and matches the
/// interpreter oracle, with routed `all_to_all` reshards appearing
/// somewhere in the sweep.
#[test]
fn prop_routed_dispatch_p11() {
    use toast::models::moe::{forward, MoeConfig};
    use toast::runtime::diff::{differential_test, DEFAULT_REL_TOL};
    let mut rng = Rng::new(0xA2A);
    let mesh = Mesh::grid(&[("expert", 2)]);
    let mut routed = 0usize;
    for case in 0..8 {
        let experts = [2i64, 4, 8][rng.below(3)];
        let capacity = 1 + rng.below(2) as i64;
        let group_size = experts * capacity * (1 + rng.below(2) as i64);
        let cfg = MoeConfig {
            experts,
            group_size,
            capacity,
            d_model: 4,
            hidden: 8,
            layers: 1,
            training: false,
        };
        let (func, _, _) = forward(&cfg);
        let nda = Nda::analyze(&func);
        // params: x, l0_wg, l0_w1, ...
        let (x, w1) = (ValueId(0), ValueId(2));
        assert_eq!(
            nda.color_of(x, 0),
            nda.color_of(w1, 0),
            "case {case} ({cfg:?}): expert dim not merged with group dim"
        );
        let actions = toast::search::build_actions(
            &func,
            &nda,
            &mesh,
            &toast::search::ActionSpaceConfig { min_color_dims: 1, ..Default::default() },
        );
        let mut found = false;
        for a in actions.iter().filter(|a| a.axis == 0 && a.assignment.contains(&(w1, 0))) {
            let mut spec = ShardingSpec::unsharded(&func);
            if spec.apply_assignment(&func, &mesh, &a.assignment, a.axis).is_err() {
                continue;
            }
            let report = differential_test(&func, &spec, &mesh, 0xE0 + case as u64)
                .unwrap_or_else(|e| panic!("case {case}: differential execution failed: {e:#}"));
            assert!(
                report.within(DEFAULT_REL_TOL),
                "case {case} ({cfg:?}): routed spec diverged: rel {}",
                report.max_rel_err
            );
            if report.stats.all_to_all > 0 {
                routed += 1;
            }
            found = true;
        }
        assert!(found, "case {case} ({cfg:?}): no expert-sharding action derived");
    }
    assert!(routed > 0, "sweep never emitted a routed all_to_all");
}

/// P6: the SPMD simulator agrees with plain evaluation for replicated
/// execution (all devices compute the full program).
#[test]
fn prop_replicated_spmd_matches_single_device() {
    let mut rng = Rng::new(0x51DE);
    let mesh = Mesh::grid(&[("a", 2)]);
    for case in 0..40 {
        let func = random_func(&mut rng);
        let inputs: Vec<Tensor> = func
            .params
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let shape: Vec<usize> = p.ty.shape.iter().map(|&d| d as usize).collect();
                if p.ty.dtype == DType::I32 {
                    Tensor::zeros(shape)
                } else {
                    Tensor::randn(shape, case as u64 * 31 + i as u64)
                }
            })
            .collect();
        let expected = toast::ir::interp::eval_func(&func, &inputs).unwrap();
        let sharded: Vec<Vec<Tensor>> =
            inputs.iter().map(|t| vec![t.clone(), t.clone()]).collect();
        let outs = toast::runtime::spmd::eval_spmd(&func, &mesh, &sharded).unwrap();
        for (ri, exp) in expected.iter().enumerate() {
            for dev in 0..2 {
                assert!(exp.max_abs_diff(&outs[ri][dev]) < 1e-6);
            }
        }
    }
}

/// P10: the wire format is lossless — random `ShardingSpec` × `Mesh` ×
/// `Solution` values round-trip through JSON to *equal* values, and a
/// reloaded spec prices to the identical symbolic cost (so a spec that
/// crossed a process boundary is indistinguishable from the original,
/// the invariant the trust-but-verify service relies on).
#[test]
fn prop_wire_roundtrip_p10() {
    use toast::api::{ModelSource, Solution, ValidationRecord};
    use toast::util::json::Json;
    let mut rng = Rng::new(0xF10);
    let meshes = [
        Mesh::grid(&[("d", 2)]),
        Mesh::grid(&[("d", 4)]),
        Mesh::grid(&[("a", 2), ("b", 2)]),
        Mesh::grid(&[("a", 1), ("b", 2), ("c", 2)]),
    ];
    let model = cost_model_for_wire();
    for case in 0..60 {
        let mesh = &meshes[case % meshes.len()];
        let func = random_func(&mut rng);
        let spec = random_spec(&func, mesh, &mut rng);

        // -- the function itself survives the wire --
        let fj = toast::api::wire::func_to_json(&func).render();
        let func_back =
            toast::api::wire::func_from_json(&Json::parse(&fj).unwrap()).unwrap();
        assert_eq!(func_back, func, "case {case}: Func drifted through JSON");

        // -- mesh and spec round-trip exactly --
        let mesh_back =
            Mesh::from_json(&Json::parse(&mesh.to_json().render()).unwrap()).unwrap();
        assert_eq!(&mesh_back, mesh, "case {case}: Mesh drifted");
        let spec_back =
            ShardingSpec::from_json(&Json::parse(&spec.to_json().render()).unwrap()).unwrap();
        assert_eq!(spec_back, spec, "case {case}: ShardingSpec drifted");

        // -- a custom topology round-trips exactly and prices identically --
        let mut topo = Topology::from_kind(HardwareKind::A100);
        topo.name = format!("rand-{case}");
        topo.tiers = (0..3)
            .map(|_| {
                toast::mesh::LinkTier::new(
                    1e9 * (1.0 + rng.below(400) as f64) + 0.125,
                    1e-7 * (1.0 + rng.below(50) as f64) + 1e-9,
                )
            })
            .collect();
        let topo_back = Topology::from_json_str(&topo.to_json_string()).unwrap();
        assert_eq!(topo_back, topo, "case {case}: Topology drifted through JSON");
        let (tm, tm_back) = (CostModel::new(topo), CostModel::new(topo_back));
        let custom = SymbolicEvaluator::new(&func, mesh, &tm);
        let custom_back = SymbolicEvaluator::new(&func, mesh, &tm_back);
        match (custom.evaluate(&spec), custom_back.evaluate(&spec)) {
            (Ok((a, _)), Ok((b, _))) => assert_eq!(
                a.runtime_s.to_bits(),
                b.runtime_s.to_bits(),
                "case {case}: reloaded topology priced differently"
            ),
            (Err(_), Err(_)) => {}
            (a, b) => panic!(
                "case {case}: topology reload changed the verdict: {:?} vs {:?}",
                a.is_ok(),
                b.is_ok()
            ),
        }

        // -- identical symbolic cost on both sides of the wire --
        let sym = SymbolicEvaluator::new(&func, mesh, &model);
        let (before, after) = (sym.evaluate(&spec), sym.evaluate(&spec_back));
        match (before, after) {
            (Ok((a, _)), Ok((b, _))) => assert_eq!(
                a.runtime_s.to_bits(),
                b.runtime_s.to_bits(),
                "case {case}: symbolic cost changed across the wire"
            ),
            (Err(_), Err(_)) => {} // both reject identically
            (a, b) => panic!(
                "case {case}: evaluator verdict changed across the wire: {:?} vs {:?}",
                a.is_ok(),
                b.is_ok()
            ),
        }

        // -- a staged spec prices bit-identically across the wire too --
        // (extends P10 to the pipeline dimension: the reloaded spec +
        // stage assignment reproduce the exact schedule price)
        let nda = Nda::analyze(&func);
        let legal = toast::pipeline::legal_boundaries(&func, &nda);
        let stage_assignment = legal.first().map(|&b| toast::api::StageAssignment {
            boundaries: vec![b],
            microbatches: 2 + case % 7,
        });
        if let Some(sa) = &stage_assignment {
            let sa_back = toast::api::StageAssignment::from_json(
                &Json::parse(&sa.to_json().render()).unwrap(),
            )
            .unwrap();
            assert_eq!(&sa_back, sa, "case {case}: StageAssignment drifted");
            let sm = toast::pipeline::cut_stages(&func, &sa.boundaries).unwrap();
            let before = toast::pipeline::schedule::price_staged_symbolic(
                &sm, &spec, mesh, &model, sa.microbatches,
            );
            let after = toast::pipeline::schedule::price_staged_symbolic(
                &sm, &spec_back, mesh, &model, sa_back.microbatches,
            );
            match (before, after) {
                (Ok(a), Ok(b)) => assert_eq!(
                    a.cost.runtime_s.to_bits(),
                    b.cost.runtime_s.to_bits(),
                    "case {case}: staged symbolic cost changed across the wire"
                ),
                (Err(_), Err(_)) => {}
                (a, b) => panic!(
                    "case {case}: staged pricing verdict changed across the wire: {:?} vs {:?}",
                    a.is_ok(),
                    b.is_ok()
                ),
            }
        }

        // -- a full Solution artifact (inline model) round-trips --
        let (cost, base) = match (
            partition(&func, &spec, mesh),
            partition(&func, &ShardingSpec::unsharded(&func), mesh),
        ) {
            (Ok((local, _)), Ok((ubase, _))) => {
                (model.evaluate(&local, mesh), model.evaluate(&ubase, mesh))
            }
            _ => continue, // partitioner rejects this spec: nothing to package
        };
        let sol = Solution {
            model: ModelSource::Inline(func.clone()),
            mesh: mesh.clone(),
            topology: Topology::from_kind(HardwareKind::A100),
            strategy: "TOAST".to_string(),
            spec,
            relative: model.relative(&cost, &base),
            oom: !model.fits(&cost),
            cost,
            base,
            // Half the artifacts carry a stage assignment on the wire.
            stages: if case % 2 == 0 { stage_assignment } else { None },
            evals: case,
            search_time_s: 0.125 * case as f64,
            validation: (case % 3 == 0).then(|| ValidationRecord {
                max_rel_err: 1.5e-5,
                max_abs_diff: 3.0e-6,
                collectives: case % 7,
                tol: 1e-4,
                pass: true,
                seed: 7,
            }),
            // A third of the artifacts carry search telemetry.
            trace: (case % 3 == 1).then(|| {
                let mut tr = toast::obs::SearchTrace::default();
                tr.push_improvement(0, 1.0);
                tr.push_improvement(case as u64 + 1, 0.5);
                tr.cache_hits = case as u64;
                tr.cache_misses = case as u64 + 2;
                tr.tree_nodes = 3 * case as u64;
                tr.transposition_merges = case as u64 / 2;
                tr.phase_us = vec![("select_expand".to_string(), 123), ("finalize".to_string(), 4)];
                tr
            }),
        };
        let back = Solution::from_json_str(&sol.to_json_string()).unwrap();
        assert_eq!(back, sol, "case {case}: Solution drifted through JSON");
        assert_eq!(back.stages, sol.stages, "case {case}: stage assignment drifted");
    }
}

fn cost_model_for_wire() -> CostModel {
    CostModel::new(Topology::from_kind(HardwareKind::A100))
}

/// P12: with every link tier equal, the hierarchical rules price flat —
/// a spec costs bit-identically no matter which (same-size) mesh axis
/// carries each sharding, because min-over-participating-links and
/// per-axis tier lookups all resolve to the same tier. The island
/// profile must notice the swap on at least some programs, or the
/// property would be vacuous.
#[test]
fn prop_equal_tiers_price_flat_p12() {
    let mesh = Mesh::grid(&[("a", 2), ("b", 2)]);
    let flat = CostModel::new(Topology::named("a100-flat-8").unwrap());
    let island = CostModel::new(Topology::named("a100-2x4-islands").unwrap());
    let mut rng = Rng::new(0xF12);
    let (mut checked, mut island_diverged) = (0, 0);
    for case in 0..80 {
        let func = random_func(&mut rng);
        let spec = random_spec(&func, &mesh, &mut rng);
        // Swap which axis carries every sharding. Both axes have size 2,
        // so legality is unchanged; only the link tiers differ.
        let mut swapped = spec.clone();
        for dims in &mut swapped.dims {
            for axes in dims {
                for a in axes.iter_mut() {
                    *a = 1 - *a;
                }
            }
        }
        let price = |m: &CostModel, s: &ShardingSpec| {
            SymbolicEvaluator::new(&func, &mesh, m)
                .evaluate(s)
                .map(|(c, _)| (c.runtime_s.to_bits(), c.peak_bytes))
        };
        match (price(&flat, &spec), price(&flat, &swapped)) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a, b, "case {case}: equal tiers noticed an axis swap\n{func}");
                checked += 1;
            }
            (Err(_), Err(_)) => continue,
            (a, b) => panic!(
                "case {case}: pricing verdict changed under the axis swap: {:?} vs {:?}",
                a.is_ok(),
                b.is_ok()
            ),
        }
        if let (Ok(a), Ok(b)) = (price(&island, &spec), price(&island, &swapped)) {
            if a != b {
                island_diverged += 1;
            }
        }
    }
    assert!(checked >= 40, "only {checked} cases priced on both sides");
    assert!(island_diverged > 0, "island profile never noticed the swap — vacuous property");
}

/// P10: the transposition-aware, batch-evaluated search finds a
/// same-or-better best cost than the legacy (action-id keys, eager
/// rollouts) configuration at the same eval budget. Fixed seed and a
/// single worker make both sides deterministic, so this is a real
/// regression gate, not a statistical one. Covers the tiny zoo plus a
/// handful of random programs.
#[test]
fn prop_transposition_search_same_or_better() {
    use toast::coordinator::experiments::{build_model, BenchScale};
    use toast::search::{build_actions, search, ActionSpaceConfig, SearchConfig};

    let model = CostModel::new(Topology::from_kind(HardwareKind::A100));
    let mesh = Mesh::grid(&[("data", 2), ("model", 2)]);
    let space = ActionSpaceConfig { min_color_dims: 1, ..Default::default() };

    let mut funcs: Vec<(String, Func)> = vec![
        ("mlp".into(), build_model(ModelKind::Mlp, BenchScale::Tiny)),
        ("attention".into(), build_model(ModelKind::Attention, BenchScale::Tiny)),
    ];
    let mut rng = Rng::new(0xBEEF);
    for case in 0..6 {
        funcs.push((format!("random-{case}"), random_func(&mut rng)));
    }

    for (name, func) in &funcs {
        let nda = Nda::analyze(func);
        let actions = build_actions(func, &nda, &mesh, &space);
        if actions.is_empty() {
            continue;
        }
        let legacy_cfg = SearchConfig {
            budget: 400,
            threads: 1,
            patience: 4,
            seed: 23,
            transpositions: false,
            batch_leaves: 0,
            ..Default::default()
        };
        let opt_cfg =
            SearchConfig { transpositions: true, batch_leaves: 8, ..legacy_cfg.clone() };
        let legacy = search(func, &mesh, &model, &actions, &legacy_cfg);
        let opt = search(func, &mesh, &model, &actions, &opt_cfg);
        assert!(
            opt.relative <= legacy.relative + 1e-9,
            "{name}: transposition search regressed: {} vs legacy {}",
            opt.relative,
            legacy.relative
        );
        assert!(opt.evals <= legacy_cfg.budget, "{name}: budget overshoot ({})", opt.evals);
        assert!(legacy.evals <= legacy_cfg.budget, "{name}: legacy overshoot ({})", legacy.evals);
    }
}
