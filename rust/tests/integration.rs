//! Cross-module integration tests: NDA → actions → MCTS → partitioner →
//! interpreter, end to end on the model zoo (scaled configurations) via
//! the session API, plus method-comparison sanity on the experiment
//! grid.

use toast::api::{CompiledModel, MctsStrategy, Solution};
use toast::baselines::Method;
use toast::coordinator::experiments::{run_grid, BenchScale};
use toast::cost::CostModel;
use toast::mesh::{HardwareKind, Mesh, Topology};
use toast::models::ModelKind;
use toast::nda::Nda;
use toast::search::{ActionSpaceConfig, SearchConfig};
use toast::sharding::{partition, validate_spec, ShardingSpec};

fn cost_model() -> CostModel {
    CostModel::new(Topology::from_kind(HardwareKind::A100))
}

fn quick_search() -> SearchConfig {
    SearchConfig { budget: 120, round: 32, threads: 2, patience: 2, seed: 3, ..Default::default() }
}

fn loose_actions() -> ActionSpaceConfig {
    ActionSpaceConfig { min_color_dims: 1, ..Default::default() }
}

/// A quick MCTS session against a compiled model.
fn quick_session(compiled: &CompiledModel, mesh: &Mesh) -> Solution {
    compiled
        .partition(mesh)
        .strategy(MctsStrategy { template: quick_search() })
        .action_config(loose_actions())
        .budget(120)
        .seed(3)
        .run()
        .expect("session runs")
}

/// The flagship invariant: every spec TOAST finds partitions into a
/// device-local program that computes the same numbers as the original.
#[test]
fn toast_specs_are_semantics_preserving_across_model_zoo() {
    for kind in [ModelKind::Mlp, ModelKind::Attention, ModelKind::Gns, ModelKind::Itx] {
        let compiled = CompiledModel::from_kind(kind, false).unwrap();
        let mesh = Mesh::grid(&[("data", 2), ("model", 2)]);
        let sol = quick_session(&compiled, &mesh);
        let v = validate_spec(compiled.func(), &sol.spec, &mesh, 7)
            .unwrap_or_else(|e| panic!("{}: {e:#}", kind.name()));
        assert!(
            v.max_abs_diff < 5e-2,
            "{}: diff {} too large (relative cost {})",
            kind.name(),
            v.max_abs_diff,
            sol.relative
        );
    }
}

#[test]
fn transformer_training_step_partition_validates() {
    // The tiny transformer is the heaviest interpreter workload; validate
    // the searched spec numerically.
    let compiled = CompiledModel::from_kind(ModelKind::T2B, false).unwrap();
    let mesh = Mesh::grid(&[("data", 2), ("model", 2)]);
    let sol = quick_session(&compiled, &mesh);
    let v = validate_spec(compiled.func(), &sol.spec, &mesh, 11).unwrap();
    assert!(v.max_abs_diff < 5e-2, "diff {}", v.max_abs_diff);
}

#[test]
fn unet_partition_validates() {
    let compiled = CompiledModel::from_kind(ModelKind::UNet, false).unwrap();
    let mesh = Mesh::grid(&[("data", 2)]);
    let sol = quick_session(&compiled, &mesh);
    let v = validate_spec(compiled.func(), &sol.spec, &mesh, 13).unwrap();
    assert!(v.max_abs_diff < 5e-2, "diff {}", v.max_abs_diff);
}

/// Every spec the search returns must price identically (≤1e-6 relative
/// cost) under the symbolic evaluator and the materialized oracle — the
/// tentpole invariant of the incremental evaluation engine.
#[test]
fn searched_specs_symbolic_cost_matches_oracle() {
    for kind in [ModelKind::Mlp, ModelKind::Attention, ModelKind::Gns] {
        let compiled = CompiledModel::from_kind(kind, false).unwrap();
        let mesh = Mesh::grid(&[("data", 2), ("model", 2)]);
        let model = cost_model();
        let sol = quick_session(&compiled, &mesh);
        let diff =
            toast::sharding::validate_symbolic_cost(compiled.func(), &sol.spec, &mesh, &model)
                .unwrap_or_else(|e| panic!("{}: {e:#}", kind.name()));
        assert!(diff < 1e-6, "{}: symbolic/oracle divergence {diff}", kind.name());
    }
}

/// Sequence sharding (the paper's Figure 5b) must be reachable and
/// numerically correct for both conflict resolutions.
#[test]
fn attention_conflict_resolutions_both_validate() {
    let func = toast::models::transformer::simple_attention(64, 16, 8, 8);
    let nda = Nda::analyze(&func);
    let a = toast::ir::ValueId(8);
    let s_color = nda.color_of(a, 0);
    let mesh = Mesh::grid(&[("s", 4)]);
    let mut distinct_stats = Vec::new();
    for order in [0u64, u64::MAX] {
        let assignment = nda.sharding_assignment(s_color, order);
        let mut spec = ShardingSpec::unsharded(&func);
        let ok: Vec<_> = assignment
            .into_iter()
            .filter(|&(v, d)| spec.check(&func, &mesh, v, d, 0).is_ok())
            .collect();
        spec.apply_assignment(&func, &mesh, &ok, 0).unwrap();
        let v = validate_spec(&func, &spec, &mesh, 5).unwrap();
        assert!(v.max_abs_diff < 1e-3, "order {order}: diff {}", v.max_abs_diff);
        distinct_stats.push(v.stats);
    }
    assert_ne!(
        distinct_stats[0], distinct_stats[1],
        "the two resolutions must lower to different collectives"
    );
}

/// All four methods run on the tiny grid and produce comparable reports.
#[test]
fn method_grid_produces_finite_costs() {
    let rows = run_grid(
        BenchScale::Tiny,
        &[ModelKind::Mlp, ModelKind::Attention],
        &[HardwareKind::A100, HardwareKind::TPUv3],
        &Method::all(),
    );
    assert_eq!(rows.len(), 2 * 2 * 4);
    for r in &rows {
        assert!(r.step_ms.is_finite() && r.step_ms > 0.0, "{r:?}");
        assert!(r.relative.is_finite(), "{r:?}");
    }
}

/// TOAST should never lose badly to AutoMap/Alpa on the bench models —
/// the paper's headline (§5.2), at reduced scale. One compiled model
/// serves all three sessions.
#[test]
fn toast_at_least_matches_automated_baselines_on_gns() {
    let compiled = CompiledModel::from_kind(ModelKind::Gns, false).unwrap();
    let mesh = Mesh::grid(&[("data", 2), ("model", 2)]);
    let run = |m: Method| {
        compiled
            .partition(&mesh)
            .method(m)
            .budget(150)
            .seed(3)
            .run()
            .unwrap_or_else(|e| panic!("{}: {e:#}", m.name()))
    };
    let toast = run(Method::Toast);
    for m in [Method::Alpa, Method::AutoMap] {
        let b = run(m);
        assert!(
            toast.relative <= b.relative * 1.15,
            "TOAST {} vs {} {}",
            toast.relative,
            m.name(),
            b.relative
        );
    }
}

/// Every method produces a valid, finite, numerically correct outcome
/// through the session API on one compiled model. Specs are not
/// compared across calls — parallel MCTS rollouts race benignly, so
/// only single-threaded runs are bit-deterministic.
#[test]
fn every_method_validates_through_the_session_api() {
    let func = ModelKind::Mlp.build_scaled();
    let mesh = Mesh::grid(&[("data", 2), ("model", 2)]);
    let compiled = CompiledModel::from_kind(ModelKind::Mlp, false).unwrap();
    for method in Method::all() {
        let sol = compiled
            .partition(&mesh)
            .method(method)
            .budget(60)
            .seed(3)
            .run()
            .unwrap_or_else(|e| panic!("{}: {e:#}", method.name()));
        assert!(sol.relative.is_finite(), "{}: {}", method.name(), sol.relative);
        let v = validate_spec(&func, &sol.spec, &mesh, 7).unwrap();
        assert!(v.max_abs_diff < 5e-2, "{}: diff {}", method.name(), v.max_abs_diff);
    }
}

/// The partition service handles a mixed workload concurrently, with
/// the trust-but-verify gate replaying every accepted spec.
#[test]
fn service_runs_mixed_workload() {
    use toast::api::ModelSource;
    use toast::coordinator::{PartitionRequest, Service};
    let svc = Service::start(3);
    let mut n = 0;
    for kind in [ModelKind::Mlp, ModelKind::Attention, ModelKind::Itx] {
        for method in [Method::Toast, Method::Manual] {
            svc.submit(PartitionRequest {
                id: 0,
                model: ModelSource::zoo(kind),
                mesh: Mesh::grid(&[("data", 2), ("model", 2)]),
                topology: Topology::from_kind(HardwareKind::A100),
                method,
                budget: 60,
                seed: 2,
                verify: true,
                no_cache: false,
            })
            .expect("service accepts requests");
            n += 1;
        }
    }
    let mut ok = 0;
    for _ in 0..n {
        let resp = svc.responses.recv().unwrap();
        let sol = resp.result.as_ref().expect("job succeeds");
        assert!(sol.validation.as_ref().expect("verified").pass);
        ok += 1;
    }
    assert_eq!(ok, n);
    let snap = svc.metrics.snapshot();
    assert!(snap.contains(&format!("verified={n}")), "{snap}");
    svc.shutdown();
}

/// Paper-scale IR builds + NDA + action space within a sane time budget
/// (the §5.3 claim that TOAST's setup is cheap and cached).
#[test]
fn paper_scale_analysis_is_fast() {
    let t0 = std::time::Instant::now();
    let func = ModelKind::T7B.build_paper();
    let nda = Nda::analyze(&func);
    let mesh = Mesh::grid(&[("data", 4), ("model", 4)]);
    let actions =
        toast::search::build_actions(&func, &nda, &mesh, &ActionSpaceConfig::default());
    assert!(!actions.is_empty());
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "T7B setup took {:?}",
        t0.elapsed()
    );
}

/// Identity partition of every zoo model round-trips the module
/// unchanged (shape-wise) and verifies as device-local.
#[test]
fn identity_partition_roundtrips_model_zoo() {
    for &kind in ModelKind::all() {
        let func = kind.build_scaled();
        let mesh = Mesh::grid(&[("d", 2)]);
        let spec = ShardingSpec::unsharded(&func);
        let (local, stats) = partition(&func, &spec, &mesh).unwrap();
        assert_eq!(stats.total_collectives(), 0, "{}", kind.name());
        assert_eq!(local.instrs.len(), func.instrs.len(), "{}", kind.name());
        toast::ir::verifier::verify_device_local_with(&local, &mesh).unwrap();
        for (a, b) in func.instrs.iter().zip(&local.instrs) {
            assert_eq!(a.ty.shape, b.ty.shape, "{}", kind.name());
        }
    }
}
