//! Offline-safe, dependency-free subset of the `anyhow` error API.
//!
//! The build environment has no access to crates.io, so the repository
//! vendors the thin slice of `anyhow` it actually uses: an opaque
//! [`Error`] with context chaining, the [`Result`] alias, the `anyhow!`,
//! `bail!` and `ensure!` macros, and the [`Context`] extension trait.
//! Semantics match upstream for this subset (Display prints the latest
//! context; `{:#}` prints the whole chain, outermost first).

use std::fmt;

/// An opaque error: a chain of messages, outermost (most recent context)
/// first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, `outer: inner: ...` like upstream.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Debug mirrors upstream's report form: message plus causes.
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "boom 42");
    }

    #[test]
    fn context_chains() {
        let e: Error = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: boom 42");
        assert_eq!(e.root_cause(), "boom 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i32> {
            let n: i32 = "nope".parse()?;
            Ok(n)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn ensure_checks() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(check(1).is_ok());
        assert_eq!(format!("{}", check(-1).unwrap_err()), "x must be positive, got -1");
    }
}
