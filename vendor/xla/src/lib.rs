//! Offline stub of the `xla` (xla-rs) API surface used by
//! [`toast::runtime`].
//!
//! The build image has no XLA/PJRT shared libraries and no network, so
//! this crate provides the exact types and signatures the runtime layer
//! compiles against. Every entry point that would touch PJRT returns a
//! clear "runtime unavailable" error; the e2e tests skip gracefully when
//! no artifacts directory exists, so these paths are never exercised in
//! CI. Swapping this for the real `xla` crate (same API subset) enables
//! the hardware path without source changes.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?` conversion
/// into `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct XlaError {
    message: String,
}

impl XlaError {
    pub fn new(message: impl Into<String>) -> XlaError {
        XlaError { message: message.into() }
    }

    fn unavailable(what: &str) -> XlaError {
        XlaError::new(format!(
            "{what}: PJRT runtime unavailable (offline xla stub; link the real xla crate)"
        ))
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for XlaError {}

/// `Result` alias matching the real crate.
pub type Result<T> = std::result::Result<T, XlaError>;

/// Element types of literals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
}

/// Native Rust types storable in a [`Literal`].
pub trait NativeType: Copy + Default + fmt::Debug + 'static {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

/// Array shape: dimensions plus element type.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host literal (stub: shape metadata only; device execution is
/// unavailable, so element data is never materialized).
#[derive(Clone, Debug)]
pub struct Literal {
    shape: ArrayShape,
}

impl Literal {
    /// Rank-1 literal from host data.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { shape: ArrayShape { dims: vec![data.len() as i64], ty: T::TY } }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = self.shape.dims.iter().product();
        let m: i64 = dims.iter().product();
        if n != m {
            return Err(XlaError::new(format!("reshape element mismatch: {n} vs {m}")));
        }
        Ok(Literal { shape: ArrayShape { dims: dims.to_vec(), ty: self.shape.ty } })
    }

    /// Copy the elements out to a host vector (unavailable in the stub).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(XlaError::unavailable("Literal::to_vec"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(self.shape.clone())
    }

    /// Split a tuple literal into its elements (unavailable in the stub).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(XlaError::unavailable("Literal::decompose_tuple"))
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from a proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_shape_roundtrip() {
        let l = Literal::vec1(&[1.0f32; 12]);
        let r = l.reshape(&[3, 4]).unwrap();
        let s = r.array_shape().unwrap();
        assert_eq!(s.dims(), &[3, 4]);
        assert_eq!(s.ty(), ElementType::F32);
        assert!(l.reshape(&[5, 5]).is_err());
    }
}
