"""AOT pipeline: lower the L2 model's step functions to HLO **text**
artifacts for the Rust PJRT runtime.

HLO text — not ``HloModuleProto.serialize()`` — is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts (all under ``artifacts/``):

* ``fwd.hlo.txt``       — logits = forward(params…, tokens)
* ``grad.hlo.txt``      — (loss, grads…) = value_and_grad on a local batch
* ``adam.hlo.txt``      — (params', m', v') = adam(params…, m…, v…, grads…)
* ``kernel_attn.hlo.txt`` — the Pallas attention kernel standalone
* ``manifest.json``     — parameter order/shapes + entry signatures

Run once via ``make artifacts``; the Rust binary is self-contained after.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model as M  # noqa: E402
from compile.kernels.attention import blocked_attention  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="primary artifact path; siblings land next to it")
    ap.add_argument("--large", action="store_true",
                    help="use the ~100M-parameter e2e_large config")
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    cfg = M.Config.e2e_large() if args.large else M.Config.e2e()
    params = M.init_params(cfg)
    names = sorted(params.keys())
    flat = [params[n] for n in names]
    specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in flat]
    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)

    def write(name: str, text: str) -> str:
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {name}: {len(text) / 1e6:.2f} MB")
        return path

    # ---- forward --------------------------------------------------------
    def fwd_flat(*args):
        ps = dict(zip(names, args[: len(names)]))
        return (M.forward(cfg, ps, args[len(names)]),)

    lowered = jax.jit(fwd_flat).lower(*specs, tok_spec)
    write("fwd.hlo.txt", to_hlo_text(lowered))

    # ---- local gradient step ---------------------------------------------
    # Exported per data-parallel degree: the device-local executable of a
    # batch-sharded partition has a smaller leading batch dim (exactly what
    # the Rust partitioner's batch sharding prescribes).
    grad_fn = M.local_grad_step(cfg)

    def grad_flat(*args):
        ps = dict(zip(names, args[: len(names)]))
        tokens, targets = args[len(names)], args[len(names) + 1]
        loss, grads = grad_fn(ps, tokens, targets)
        return (loss, *[grads[n] for n in names])

    for dp in (1, 2, 4):
        if cfg.batch % dp != 0:
            continue
        local = jax.ShapeDtypeStruct((cfg.batch // dp, cfg.seq), jnp.int32)
        lowered = jax.jit(grad_flat).lower(*specs, local, local)
        name = "grad.hlo.txt" if dp == 1 else f"grad_dp{dp}.hlo.txt"
        write(name, to_hlo_text(lowered))

    # ---- adam apply -------------------------------------------------------
    adam_fn = M.adam_apply(lr=5e-3)

    def adam_flat(*args):
        n = len(names)
        ps = dict(zip(names, args[:n]))
        m = dict(zip(names, args[n : 2 * n]))
        v = dict(zip(names, args[2 * n : 3 * n]))
        g = dict(zip(names, args[3 * n : 4 * n]))
        np_, nm, nv = adam_fn(ps, m, v, g)
        return tuple(
            [np_[k] for k in names] + [nm[k] for k in names] + [nv[k] for k in names]
        )

    lowered = jax.jit(adam_flat).lower(*(specs * 4))
    write("adam.hlo.txt", to_hlo_text(lowered))

    # ---- standalone attention kernel ----------------------------------------
    q_spec = jax.ShapeDtypeStruct(
        (cfg.batch, cfg.heads, cfg.seq, cfg.key_size), jnp.float32
    )
    lowered = jax.jit(lambda q, k, v: (blocked_attention(q, k, v),)).lower(
        q_spec, q_spec, q_spec
    )
    write("kernel_attn.hlo.txt", to_hlo_text(lowered))

    # ---- manifest --------------------------------------------------------------
    manifest = {
        "config": {
            "d_model": cfg.d_model,
            "layers": cfg.layers,
            "hidden": cfg.hidden,
            "heads": cfg.heads,
            "key_size": cfg.key_size,
            "vocab": cfg.vocab,
            "batch": cfg.batch,
            "seq": cfg.seq,
            "param_count": cfg.param_count(),
        },
        "param_names": names,
        "param_shapes": {n: list(params[n].shape) for n in names},
        "entries": {
            "fwd": {"file": "fwd.hlo.txt", "inputs": "params + tokens", "outputs": "(logits,)"},
            "grad": {
                "file": "grad.hlo.txt",
                "inputs": "params + tokens + targets",
                "outputs": "(loss, grads...)",
            },
            "adam": {
                "file": "adam.hlo.txt",
                "inputs": "params + m + v + grads",
                "outputs": "(params', m', v')",
            },
            "kernel_attn": {
                "file": "kernel_attn.hlo.txt",
                "inputs": "q, k, v [batch, heads, seq, key]",
                "outputs": "(out,)",
            },
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")

    # keep the Makefile's primary target fresh
    with open(args.out, "w") as f:
        f.write("# see sibling artifacts: fwd/grad/adam/kernel_attn .hlo.txt\n")


if __name__ == "__main__":
    main()
