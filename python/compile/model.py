"""L2: the JAX transformer model (fwd/bwd/Adam), calling the L1 Pallas
kernel for attention. Build-time only — ``aot.py`` lowers the jitted step
functions to HLO text once; the Rust coordinator loads and executes the
artifacts via PJRT with Python never on the request path.

The exported functions deliberately mirror the Rust model zoo's
transformer (rank-3 attention weights, RMSNorm, GeGLU) so the Rust-side
partitioning decisions map one-to-one onto the executable artifacts.
"""

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from compile.kernels.attention import blocked_attention
from compile.kernels.ref import rmsnorm_ref


@dataclass(frozen=True)
class Config:
    """Model shape. `e2e()` is the default end-to-end-example size;
    `e2e_large()` is the ~100M-parameter driver configuration."""

    d_model: int = 128
    layers: int = 2
    hidden: int = 512
    heads: int = 4
    key_size: int = 32
    vocab: int = 1024
    batch: int = 8
    seq: int = 128

    @staticmethod
    def e2e():
        return Config()

    @staticmethod
    def e2e_large():
        # ~100M parameters: a GPT-2-small-shaped model for the end-to-end
        # training driver.
        return Config(
            d_model=768, layers=12, hidden=3072, heads=12, key_size=64,
            vocab=32768, batch=8, seq=256,
        )

    def param_count(self) -> int:
        attn = (
            3 * self.d_model * self.heads * self.key_size
            + self.heads * self.key_size * self.d_model
        )
        mlp = 3 * self.d_model * self.hidden
        return (
            self.vocab * self.d_model
            + self.layers * (attn + mlp + 2 * self.d_model)
            + self.d_model
        )


def init_params(cfg: Config, seed: int = 0) -> Dict[str, jnp.ndarray]:
    key = jax.random.PRNGKey(seed)
    params = {}

    def take(shape, scale):
        nonlocal key
        key, sub = jax.random.split(key)
        return jax.random.normal(sub, shape, jnp.float32) * scale

    params["embedding"] = take((cfg.vocab, cfg.d_model), 0.02)
    for l in range(cfg.layers):
        d, h, k = cfg.d_model, cfg.heads, cfg.key_size
        params[f"l{l}_ln1"] = jnp.ones((d,), jnp.float32)
        params[f"l{l}_wq"] = take((d, h, k), d ** -0.5)
        params[f"l{l}_wk"] = take((d, h, k), d ** -0.5)
        params[f"l{l}_wv"] = take((d, h, k), d ** -0.5)
        params[f"l{l}_wo"] = take((h, k, d), (h * k) ** -0.5)
        params[f"l{l}_ln2"] = jnp.ones((d,), jnp.float32)
        params[f"l{l}_wgate"] = take((d, cfg.hidden), d ** -0.5)
        params[f"l{l}_wup"] = take((d, cfg.hidden), d ** -0.5)
        params[f"l{l}_wdown"] = take((cfg.hidden, d), cfg.hidden ** -0.5)
    params["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    return params


def forward(cfg: Config, params: Dict[str, jnp.ndarray], tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens [batch, seq] int32 -> logits [batch, seq, vocab]."""
    x = params["embedding"][tokens]  # [B,S,D]
    for l in range(cfg.layers):
        xn = rmsnorm_ref(x, params[f"l{l}_ln1"])
        q = jnp.einsum("bsd,dhk->bhsk", xn, params[f"l{l}_wq"])
        k = jnp.einsum("bsd,dhk->bhsk", xn, params[f"l{l}_wk"])
        v = jnp.einsum("bsd,dhk->bhsk", xn, params[f"l{l}_wv"])
        ctx = blocked_attention(q, k, v)  # L1 Pallas kernel
        attn_out = jnp.einsum("bhsk,hkd->bsd", ctx, params[f"l{l}_wo"])
        x = x + attn_out
        xn2 = rmsnorm_ref(x, params[f"l{l}_ln2"])
        gate = xn2 @ params[f"l{l}_wgate"]
        up = xn2 @ params[f"l{l}_wup"]
        act = gate * jax.nn.sigmoid(1.702 * gate)
        x = x + (act * up) @ params[f"l{l}_wdown"]
    xf = rmsnorm_ref(x, params["final_norm"])
    return jnp.einsum("bsd,vd->bsv", xf, params["embedding"])


def loss_fn(cfg: Config, params, tokens, targets) -> jnp.ndarray:
    """Next-token cross-entropy."""
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def local_grad_step(cfg: Config):
    """Per-device function for the Rust data-parallel coordinator: compute
    loss and gradients on the *local* batch shard. The cross-device
    gradient all-reduce is performed by the Rust L3 layer between PJRT
    executions (host collective over simulated devices)."""

    def fn(params, tokens, targets):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens, targets))(params)
        return loss, grads

    return fn


def adam_apply(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    """Adam update: (params, m, v, grads) -> (params', m', v'). Exported as
    its own artifact so the coordinator applies updates after reducing
    gradients."""

    def fn(params, m, v, grads):
        new_p, new_m, new_v = {}, {}, {}
        for k in params:
            g = grads[k]
            new_m[k] = b1 * m[k] + (1 - b1) * g
            new_v[k] = b2 * v[k] + (1 - b2) * jnp.square(g)
            new_p[k] = params[k] - lr * new_m[k] / (jnp.sqrt(new_v[k]) + eps)
        return new_p, new_m, new_v

    return fn


def synthetic_batch(cfg: Config, seed: int, batch: int | None = None):
    """Synthetic 'permuted shift' corpus: the target is a fixed
    permutation of the next token — learnable structure, so the e2e loss
    curve visibly drops below the ln(vocab) entropy floor."""
    b = batch or cfg.batch
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (b, cfg.seq), 0, cfg.vocab, jnp.int32)
    perm = (jnp.arange(cfg.vocab, dtype=jnp.int32) * 7 + 3) % cfg.vocab
    targets = perm[jnp.roll(tokens, -1, axis=1)]
    return tokens, targets
