"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must match its reference here to float32
tolerance across the hypothesis-swept shape/dtype grid in
``python/tests/test_kernel.py``.
"""

import jax.numpy as jnp


def attention_ref(q, k, v):
    """Reference multi-head attention: q/k/v [batch, heads, seq, d]."""
    d = q.shape[-1]
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x, scale, eps=1e-6):
    """RMSNorm over the last dim."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jnp.reciprocal(jnp.sqrt(var + eps)) * scale).astype(x.dtype)


def mlp_ref(x, w_gate, w_up, w_down):
    """GeGLU MLP block reference."""
    gate = x @ w_gate
    up = x @ w_up
    act = gate * (1.0 / (1.0 + jnp.exp(-1.702 * gate)))  # gelu approx
    return (act * up) @ w_down
