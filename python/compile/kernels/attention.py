"""L1: Pallas blocked attention kernel (online softmax / flash-attention
style), the compute hot-spot of the transformer model TOAST partitions.

TPU thinking (DESIGN.md §Hardware-Adaptation): Q is tiled into
``(BLOCK_Q, d)`` VMEM blocks via the grid; K/V stream through VMEM in
``BLOCK_KV`` chunks inside the kernel; the S×S score tile never
materializes in HBM — the sequence dimension is exactly the dimension
whose sharding conflict TOAST's NDA resolves (paper §3.3), so the kernel's
KV-blocking matches the `all_gather k` / `reduce_scatter z` decomposition
of Figure 5b. Block sizes target MXU-friendly multiples; ``interpret=True``
is mandatory on CPU (real TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot execute).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. On a real TPU these would be 128-multiples to fill
# the MXU systolic array; kept adaptive so tiny test shapes work in
# interpret mode.
BLOCK_Q = 128
BLOCK_KV = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_kv: int, scale: float):
    """One (batch*head, q-block) grid cell: online-softmax accumulation
    over KV blocks. q_ref: [bq, d]; k_ref/v_ref: [S, d]; o_ref: [bq, d].
    """
    q = q_ref[...].astype(jnp.float32) * scale
    seq = k_ref.shape[0]
    bq, d = q.shape
    n_kv = seq // block_kv

    def body(i, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(k_ref, (pl.dslice(i * block_kv, block_kv), slice(None)))
        v = pl.load(v_ref, (pl.dslice(i * block_kv, block_kv), slice(None)))
        s = q @ k.astype(jnp.float32).T  # [bq, block_kv]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def _attention_ref_for_vjp(q, k, v):
    """f32 reference used for the backward pass (Pallas interpret-mode
    kernels do not support reverse-mode autodiff; pairing a fused forward
    kernel with a recomputing backward is standard flash-attention
    practice)."""
    d = q.shape[-1]
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def blocked_attention(q, k, v, block_q: int = BLOCK_Q, block_kv: int = BLOCK_KV):
    """Multi-head attention via the Pallas kernel.

    Shapes: q/k/v ``[batch, heads, seq, d]`` -> ``[batch, heads, seq, d]``.
    Causal masking is omitted (matches the paper's Figure 5 formulation).
    """
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0, "seq must divide blocks"
    scale = 1.0 / (d ** 0.5)

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)

    kernel = functools.partial(_attn_kernel, block_kv=block_kv, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


def vmem_estimate_bytes(block_q: int, block_kv: int, d: int, dtype_bytes: int = 4) -> int:
    """Estimated per-core VMEM footprint of one grid cell: the Q tile, one
    K and one V tile, the score tile, and the f32 accumulators. Used by
    DESIGN.md §Perf to pick block sizes under the ~16 MiB VMEM budget."""
    q_tile = block_q * d * dtype_bytes
    kv_tiles = 2 * block_kv * d * dtype_bytes
    score = block_q * block_kv * 4
    acc = block_q * d * 4 + 2 * block_q * 4
    return q_tile + kv_tiles + score + acc


def mxu_utilization_estimate(block_q: int, block_kv: int, d: int) -> float:
    """Fraction of 128x128 MXU tiles usefully filled by the two matmuls of
    one KV step (structure-level estimate; interpret-mode wallclock is not
    a TPU proxy)."""
    def eff(m, n, k):
        pad = lambda x: ((x + 127) // 128) * 128
        return (m * n * k) / (pad(m) * pad(n) * pad(k))

    # s = q @ k^T : [bq, d] x [d, bkv]; acc += p @ v : [bq, bkv] x [bkv, d]
    return 0.5 * (eff(block_q, block_kv, d) + eff(block_q, d, block_kv))


def _blocked_attention_fwd(q, k, v, block_q, block_kv):
    return blocked_attention(q, k, v, block_q, block_kv), (q, k, v)


def _blocked_attention_bwd(block_q, block_kv, res, g):
    q, k, v = res
    _, vjp = jax.vjp(_attention_ref_for_vjp, q, k, v)
    return vjp(g)


blocked_attention.defvjp(_blocked_attention_fwd, _blocked_attention_bwd)
