"""L2 correctness: model shapes, loss behaviour, Adam training, and the
AOT HLO-text export round-trip."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.aot import to_hlo_text


def tiny_cfg():
    return M.Config(d_model=16, layers=1, hidden=32, heads=2, key_size=8,
                    vocab=64, batch=2, seq=32)


def test_forward_shapes():
    cfg = tiny_cfg()
    params = M.init_params(cfg)
    tokens, _ = M.synthetic_batch(cfg, 0)
    logits = M.forward(cfg, params, tokens)
    assert logits.shape == (cfg.batch, cfg.seq, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_starts_near_entropy_floor():
    cfg = tiny_cfg()
    params = M.init_params(cfg)
    tokens, targets = M.synthetic_batch(cfg, 0)
    loss = M.loss_fn(cfg, params, tokens, targets)
    # random init -> loss ~ ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


def test_grad_step_produces_full_grads():
    cfg = tiny_cfg()
    params = M.init_params(cfg)
    tokens, targets = M.synthetic_batch(cfg, 1)
    loss, grads = M.local_grad_step(cfg)(params, tokens, targets)
    assert set(grads.keys()) == set(params.keys())
    assert float(loss) > 0
    total = sum(float(jnp.sum(jnp.abs(g))) for g in grads.values())
    assert total > 0


def test_adam_training_reduces_loss():
    cfg = tiny_cfg()
    params = M.init_params(cfg)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}
    grad_fn = jax.jit(M.local_grad_step(cfg))
    adam = jax.jit(M.adam_apply(lr=5e-3))
    tokens, targets = M.synthetic_batch(cfg, 2)
    losses = []
    for _ in range(30):
        loss, grads = grad_fn(params, tokens, targets)
        losses.append(float(loss))
        params, m, v = adam(params, m, v, grads)
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_data_parallel_grads_match_full_batch():
    """The Rust coordinator's DP scheme: mean of per-shard grads equals
    the full-batch grad (loss is a mean, shards are equal-sized)."""
    cfg = tiny_cfg()
    params = M.init_params(cfg)
    tokens, targets = M.synthetic_batch(cfg, 3)
    grad_fn = M.local_grad_step(cfg)
    _, full = grad_fn(params, tokens, targets)
    half = cfg.batch // 2
    _, g0 = grad_fn(params, tokens[:half], targets[:half])
    _, g1 = grad_fn(params, tokens[half:], targets[half:])
    for k in full:
        avg = (g0[k] + g1[k]) / 2.0
        np.testing.assert_allclose(np.asarray(avg), np.asarray(full[k]), atol=1e-5)


def test_hlo_text_export_roundtrip():
    cfg = tiny_cfg()
    params = M.init_params(cfg)
    names = sorted(params.keys())
    specs = [jax.ShapeDtypeStruct(params[n].shape, params[n].dtype) for n in names]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)

    def fwd_flat(*args):
        ps = dict(zip(names, args[: len(names)]))
        return (M.forward(cfg, ps, args[len(names)]),)

    lowered = jax.jit(fwd_flat).lower(*specs, tok)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "dot(" in text or "dot." in text


def test_synthetic_batch_is_deterministic_and_learnable():
    cfg = tiny_cfg()
    t1, y1 = M.synthetic_batch(cfg, 7)
    t2, y2 = M.synthetic_batch(cfg, 7)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    # target is a function of the next token: same next token -> same target
    perm = (np.arange(cfg.vocab) * 7 + 3) % cfg.vocab
    nxt = np.roll(np.asarray(t1), -1, axis=1)
    np.testing.assert_array_equal(np.asarray(y1), perm[nxt])
