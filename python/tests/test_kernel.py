"""L1 correctness: the Pallas attention kernel vs the pure-jnp oracle,
hypothesis-swept across shapes and dtypes — the core correctness signal
for the compile path."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (
    blocked_attention,
    mxu_utilization_estimate,
    vmem_estimate_bytes,
)
from compile.kernels.ref import attention_ref, mlp_ref, rmsnorm_ref


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


@settings(max_examples=20, deadline=None)
@given(
    batch=st.sampled_from([1, 2]),
    heads=st.sampled_from([1, 2, 4]),
    seq=st.sampled_from([64, 128, 256]),
    d=st.sampled_from([16, 32, 64]),
    block_q=st.sampled_from([32, 64, 128]),
    block_kv=st.sampled_from([32, 64]),
)
def test_attention_matches_ref_shapes(batch, heads, seq, d, block_q, block_kv):
    q = rand(1, (batch, heads, seq, d), jnp.float32)
    k = rand(2, (batch, heads, seq, d), jnp.float32)
    v = rand(3, (batch, heads, seq, d), jnp.float32)
    out = blocked_attention(q, k, v, block_q=block_q, block_kv=block_kv)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_attention_dtypes(dtype, atol):
    q = rand(4, (2, 2, 128, 32), dtype)
    k = rand(5, (2, 2, 128, 32), dtype)
    v = rand(6, (2, 2, 128, 32), dtype)
    out = blocked_attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol, rtol=atol
    )


def test_attention_rows_are_convex_combinations():
    # attention output must lie within the convex hull of V rows
    q = rand(7, (1, 1, 64, 16), jnp.float32)
    k = rand(8, (1, 1, 64, 16), jnp.float32)
    v = jnp.ones((1, 1, 64, 16), jnp.float32) * 3.0
    out = blocked_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), 3.0, atol=1e-5)


def test_single_kv_block_degenerates_to_softmax():
    q = rand(9, (1, 1, 32, 8), jnp.float32)
    k = rand(10, (1, 1, 32, 8), jnp.float32)
    v = rand(11, (1, 1, 32, 8), jnp.float32)
    out = blocked_attention(q, k, v, block_q=32, block_kv=32)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_vmem_estimate_under_budget():
    # DESIGN.md §Perf: the default block shapes must fit TPU VMEM (~16 MiB)
    assert vmem_estimate_bytes(128, 128, 256) < 16 * 1024 * 1024
    assert vmem_estimate_bytes(512, 512, 256) < 16 * 1024 * 1024


def test_mxu_utilization_prefers_aligned_blocks():
    aligned = mxu_utilization_estimate(128, 128, 128)
    ragged = mxu_utilization_estimate(100, 100, 100)
    assert aligned == 1.0
    assert ragged < aligned


def test_rmsnorm_ref_unit_variance():
    x = rand(12, (4, 64), jnp.float32)
    out = rmsnorm_ref(x, jnp.ones((64,)))
    rms = np.sqrt(np.mean(np.square(np.asarray(out)), axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_mlp_ref_shapes():
    x = rand(13, (4, 8), jnp.float32)
    wg = rand(14, (8, 32), jnp.float32)
    wu = rand(15, (8, 32), jnp.float32)
    wd = rand(16, (32, 8), jnp.float32)
    out = mlp_ref(x, wg, wu, wd)
    assert out.shape == (4, 8)
