//! Distributed partition service demo — the whole socket story in one
//! process, deterministic enough for CI:
//!
//! 1. a `TcpServer` on an ephemeral port (no local worker threads),
//! 2. two real worker loops (`run_worker_on`) on background threads —
//!    the same compiled-model-cache + trust-but-verify path the
//!    `toast worker --connect` process runs,
//! 3. one deliberately crashing worker that accepts a job and dies
//!    mid-request, proving heartbeat/EOF liveness detection and the
//!    front-of-queue requeue,
//! 4. a `ServiceClient` that submits a zoo workload, collects every
//!    verified solution, and checks the status counters over the wire.
//!
//! Exits nonzero if any response is missing, unverified, or the requeue
//! accounting is off — CI runs this as an executable spec of the
//! transport's guarantees.

use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use toast::api::wire::Message;
use toast::baselines::Method;
use toast::coordinator::service::default_request;
use toast::coordinator::transport::{read_message, run_worker_on, write_message, MAX_FRAME_LEN};
use toast::coordinator::{
    Service, ServiceClient, ServiceConfig, TcpServer, TcpServerConfig, WorkerOptions,
};
use toast::models::ModelKind;

fn worker_opts(name: &str) -> WorkerOptions {
    WorkerOptions {
        name: name.to_string(),
        service: ServiceConfig { workers: 0, search_threads: 1, ..Default::default() },
    }
}

fn main() -> anyhow::Result<()> {
    // -- server ------------------------------------------------------------
    let svc = Service::start_with(ServiceConfig {
        workers: 0, // every worker arrives over the socket
        search_threads: 1,
        ..Default::default()
    });
    let listener = TcpListener::bind("127.0.0.1:0")?;
    // Two pipelined jobs per worker connection, like CI's serve flags.
    let server = TcpServer::start(
        svc,
        listener,
        TcpServerConfig { dead_after: Duration::from_secs(2), capacity: 2, ..Default::default() },
    )?;
    let addr = server.local_addr();
    println!("server listening on {addr}");

    // -- a worker that will crash mid-request ------------------------------
    let crasher = std::thread::spawn(move || -> anyhow::Result<u64> {
        let stream = TcpStream::connect(addr)?;
        let mut rd = stream.try_clone()?;
        let mut wr = stream;
        write_message(&mut wr, &Message::Register { name: "crasher".into() })?;
        let Some(Message::Registered { worker_id }) = read_message(&mut rd, MAX_FRAME_LEN)?
        else {
            anyhow::bail!("no registration ack");
        };
        // Take exactly one job, then die without answering.
        loop {
            match read_message(&mut rd, MAX_FRAME_LEN)? {
                Some(Message::Job(req)) => {
                    println!("crasher (worker #{worker_id}) took request {} and died", req.id);
                    return Ok(req.id);
                }
                Some(_) => continue,
                None => anyhow::bail!("server closed before dispatching"),
            }
        }
    });

    // -- client: submit the workload while only the crasher is attached ----
    let mut client = ServiceClient::connect(&addr.to_string())?;
    let workload: Vec<(ModelKind, Method)> = [ModelKind::Mlp, ModelKind::Attention, ModelKind::Itx]
        .into_iter()
        .flat_map(|m| [(m, Method::Toast), (m, Method::Manual)])
        .collect();
    let mut expected = Vec::new();
    for &(model, method) in &workload {
        let mut req = default_request(model, method);
        req.budget = 100;
        req.seed = 1;
        expected.push(client.submit(req)?);
    }
    println!("submitted {} requests", expected.len());

    // The crash happens with a request in flight...
    let crashed_id = crasher.join().expect("crasher thread")?;
    println!("request {crashed_id} was in flight when its worker died");

    // ...and two honest workers mop everything up, crashed job included.
    let survivors: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).expect("connect worker");
                run_worker_on(stream, &worker_opts(&format!("survivor-{i}"))).expect("worker loop");
            })
        })
        .collect();

    let mut verified = 0;
    for _ in 0..expected.len() {
        let resp = client.recv_response()?;
        let sol = resp.result.map_err(|e| anyhow::anyhow!("job {} failed: {e:#}", resp.id))?;
        let pass = sol.validation.as_ref().map(|v| v.pass).unwrap_or(false);
        anyhow::ensure!(pass, "job {} arrived unverified", resp.id);
        verified += 1;
        println!("job {:>2}: {}", resp.id, sol.summarize());
    }

    let report = client.status()?;
    println!("status: {}", report.render_line());
    anyhow::ensure!(verified == expected.len(), "missing responses");
    anyhow::ensure!(report.requeued >= 1, "the crash must have requeued a request");
    anyhow::ensure!(report.failed == 0, "no request may be lost or failed");
    anyhow::ensure!(report.queued == 0 && report.in_flight == 0, "queue must drain");

    server.shutdown();
    for s in survivors {
        s.join().expect("survivor exits cleanly on shutdown");
    }
    println!(
        "OK — {} requests served over the socket, {} requeued after a worker crash, all verified",
        expected.len(),
        report.requeued
    );
    Ok(())
}
