//! Profiling probe for the search hot path (used during the §Perf pass).
use std::time::Instant;
use toast::coordinator::experiments::{build_model, measure_eval_throughput, BenchScale};
use toast::cost::symbolic::SymbolicEvaluator;
use toast::cost::CostModel;
use toast::mesh::{HardwareKind, Mesh, Topology};
use toast::models::ModelKind;
use toast::nda::Nda;
use toast::search::*;
use toast::sharding::{partition, ShardingSpec};

fn main() {
    let func = build_model(ModelKind::T2B, BenchScale::Bench);
    let mesh = Mesh::grid(&[("data", 4), ("model", 4)]);
    let model = CostModel::new(Topology::from_kind(HardwareKind::A100));
    let nda = Nda::analyze(&func);
    let actions = build_actions(&func, &nda, &mesh, &ActionSpaceConfig::default());
    println!("{} actions, {} instrs", actions.len(), func.instrs.len());

    // breakdown: spec clone, apply, partition, symbolic eval
    let t0 = Instant::now();
    let spec = ShardingSpec::unsharded(&func);
    for _ in 0..1000 { std::hint::black_box(spec.clone()); }
    println!("spec clone:      {:>10.1?}/it", t0.elapsed() / 1000);

    let t0 = Instant::now();
    for _ in 0..1000 {
        let mut s = spec.clone();
        s.apply_assignment(&func, &mesh, &actions[0].assignment, actions[0].axis).unwrap();
    }
    println!("clone+apply:     {:>10.1?}/it", t0.elapsed() / 1000);

    // legal_actions-equivalent cost: probe all actions against a spec
    let t0 = Instant::now();
    for _ in 0..100 {
        for a in &actions {
            std::hint::black_box(spec.check_assignment(&func, &mesh, &a.assignment, a.axis));
        }
    }
    println!("probe-all ({}):  {:>10.1?}/it", actions.len(), t0.elapsed() / 100);

    let t0 = Instant::now();
    for _ in 0..100 { std::hint::black_box(partition(&func, &spec, &mesh).unwrap()); }
    println!("partition:       {:>10.1?}/it", t0.elapsed() / 100);

    let sym = SymbolicEvaluator::new(&func, &mesh, &model);
    let t0 = Instant::now();
    for _ in 0..100 { std::hint::black_box(sym.evaluate(&spec).unwrap()); }
    println!("symbolic eval:   {:>10.1?}/it", t0.elapsed() / 100);

    // evaluator throughput: the transformer quickstart config, all three
    // evaluators over the same trajectory of states
    let tp = measure_eval_throughput(&func, &mesh, &model, &actions, 12, 20);
    println!("{}", tp.format());

    // full search timing
    let t0 = Instant::now();
    let out = search(&func, &mesh, &model, &actions, &SearchConfig { budget: 150, seed: 1, ..Default::default() });
    println!("search(150):     {:>10.1?} total, {} evals, rel {:.4}", t0.elapsed(), out.evals, out.relative);
}
