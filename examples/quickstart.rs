//! Quickstart: the session API end to end on the paper's running
//! example (the two-layer MLP of Figure 2).
//!
//! 1. build the model IR;
//! 2. **compile once** — verify + Named Dimension Analysis (§3);
//! 3. run a partitioning **session** on a mesh (MCTS, §4);
//! 4. ship the resulting `Solution` artifact through its JSON wire
//!    format and prove the round-trip is exact;
//! 5. apply the reloaded spec and numerically validate it against the
//!    interpreter oracle (differential execution on the SPMD simulator).
//!
//! Run: `cargo run --release --example quickstart`

use toast::api::{CompiledModel, Solution};
use toast::ir::{FuncBuilder, TensorType, ValueId};
use toast::mesh::Mesh;
use toast::search::ActionSpaceConfig;
use toast::sharding::partition;

fn main() -> anyhow::Result<()> {
    // ---- the model (paper Figure 2a) -------------------------------------
    let mut b = FuncBuilder::new("mlp");
    let x = b.param("x", TensorType::f32(vec![256, 32]));
    let w1 = b.param("w1", TensorType::f32(vec![32, 64]));
    let w2 = b.param("w2", TensorType::f32(vec![64, 16]));
    let y = b.matmul(x, w1);
    let z = b.relu(y);
    let w = b.matmul(z, w2);
    let func = b.build(vec![w]);
    println!("{func}");

    // ---- compile once: verifier + NDA (paper §3) --------------------------
    let compiled = CompiledModel::compile(func)?;
    let nda = compiled.nda();
    println!("NDA found {} colors:", nda.num_colors());
    for c in 0..nda.num_colors() {
        let info = &nda.colors[c];
        let members: Vec<String> = info
            .members
            .iter()
            .map(|&(v, d)| format!("{}.{d}", compiled.func().value_name(v)))
            .collect();
        println!("  color {c} (size {:>4}): {}", info.dim_size, members.join(", "));
    }

    // ---- a partitioning session over a 4x2 mesh (Figure 2c is b x m) ------
    let mesh = Mesh::grid(&[("b", 4), ("m", 2)]);
    let solution = compiled
        .partition(&mesh)
        .action_config(ActionSpaceConfig { min_color_dims: 1, ..Default::default() })
        .budget(200)
        .seed(7)
        .validate(true) // differentially execute the winning spec
        .run()?;
    println!("\nsession outcome: {}", solution.summarize());
    for (pi, p) in compiled.func().params.iter().enumerate() {
        println!(
            "  %{:<4} {}",
            p.name,
            solution.spec.describe_value(compiled.func(), &mesh, ValueId(pi as u32))
        );
    }

    // ---- the artifact crosses a process boundary --------------------------
    let wire = solution.to_json_string();
    println!("\nserialized solution: {} bytes of JSON", wire.len());
    let reloaded = Solution::from_json_str(&wire)?;
    assert_eq!(reloaded, solution, "wire round-trip must be exact");
    println!("round-trip OK — spec, cost report and validation record identical");

    // ---- apply the reloaded spec (paper Figure 2b/2c) ---------------------
    // (through the session compiler: wire-loaded IR re-passes the verifier)
    let applied = CompiledModel::from_source(&reloaded.model)?;
    reloaded.spec.check_against(applied.func(), &reloaded.mesh)?;
    let (local, stats) = partition(applied.func(), &reloaded.spec, &reloaded.mesh)?;
    println!("\ndevice-local program ({stats:?}):\n{local}");

    // ---- the numeric proof came with the artifact -------------------------
    let v = reloaded.validation.expect("session ran with validate(true)");
    println!(
        "differential validation: max relative divergence {:.3e} (tol {:.1e})",
        v.max_rel_err, v.tol
    );
    assert!(v.pass);
    println!("OK — sharded execution matches the unsharded program.");
    Ok(())
}
