//! Quickstart: analyze and auto-partition the paper's running example
//! (the two-layer MLP of Figure 2) end to end, then numerically validate
//! the partitioned program.
//!
//! Run: `cargo run --release --example quickstart`

use toast::cost::CostModel;
use toast::ir::{FuncBuilder, TensorType, ValueId};
use toast::mesh::{HardwareKind, HardwareProfile, Mesh};
use toast::nda::Nda;
use toast::search::{auto_partition, ActionSpaceConfig, SearchConfig};
use toast::sharding::{partition, validate_spec};

fn main() -> anyhow::Result<()> {
    // ---- the model (paper Figure 2a) -------------------------------------
    let mut b = FuncBuilder::new("mlp");
    let x = b.param("x", TensorType::f32(vec![256, 32]));
    let w1 = b.param("w1", TensorType::f32(vec![32, 64]));
    let w2 = b.param("w2", TensorType::f32(vec![64, 16]));
    let y = b.matmul(x, w1);
    let z = b.relu(y);
    let w = b.matmul(z, w2);
    let func = b.build(vec![w]);
    println!("{func}");

    // ---- the Named Dimension Analysis (paper §3) --------------------------
    let nda = Nda::analyze(&func);
    println!("NDA found {} colors:", nda.num_colors());
    for c in 0..nda.num_colors() {
        let info = &nda.colors[c];
        let members: Vec<String> = info
            .members
            .iter()
            .map(|&(v, d)| format!("{}.{d}", func.value_name(v)))
            .collect();
        println!("  color {c} (size {:>4}): {}", info.dim_size, members.join(", "));
    }

    // ---- auto-partition over a 4x2 mesh (paper Figure 2c is b x m) --------
    let mesh = Mesh::grid(&[("b", 4), ("m", 2)]);
    let model = CostModel::new(HardwareProfile::new(HardwareKind::A100));
    let out = auto_partition(
        &func,
        &mesh,
        &model,
        &ActionSpaceConfig { min_color_dims: 1, ..Default::default() },
        &SearchConfig { budget: 200, seed: 7, ..Default::default() },
    );
    println!(
        "\nMCTS found {} actions (relative cost {:.3}, {} evaluations, {:?}):",
        out.actions.len(),
        out.relative,
        out.evals,
        out.wall
    );
    for (pi, p) in func.params.iter().enumerate() {
        println!(
            "  %{:<4} {}",
            p.name,
            out.spec.describe_value(&func, &mesh, ValueId(pi as u32))
        );
    }

    // ---- the device-local program (paper Figure 2b/2c) --------------------
    let (local, stats) = partition(&func, &out.spec, &mesh)?;
    println!("\ndevice-local program ({stats:?}):\n{local}");

    // ---- numeric proof -----------------------------------------------------
    let v = validate_spec(&func, &out.spec, &mesh, 3)?;
    println!("numeric validation: max |Δ| = {:.3e}", v.max_abs_diff);
    assert!(v.max_abs_diff < 1e-3);
    println!("OK — sharded execution matches the unsharded program.");
    Ok(())
}
