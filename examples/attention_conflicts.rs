//! Walkthrough of the paper's §3.3–3.5: sharding conflicts in attention,
//! their compatibility set, and the two resolutions — one of which is
//! sequence sharding (Figure 5b: `all_gather k` + `reduce_scatter z`).
//!
//! Run: `cargo run --release --example attention_conflicts`

use toast::ir::ValueId;
use toast::mesh::Mesh;
use toast::models::transformer::simple_attention;
use toast::nda::Nda;
use toast::sharding::{partition, validate_spec, ShardingSpec};

fn main() -> anyhow::Result<()> {
    // Paper Figure 5a, at an executable size.
    let func = simple_attention(128, 32, 16, 16);
    println!("{func}");

    let nda = Nda::analyze(&func);
    println!(
        "conflicts: {} (paper Figure 5d shows 5); raw resolutions: {}",
        nda.conflicts.conflicts.len(),
        nda.conflicts.raw_resolution_count()
    );
    println!(
        "compatibility sets: {} -> resolution groups: {} (so only {} real choices)",
        nda.conflicts.compat_sets.len(),
        nda.conflicts.num_groups(),
        1u64 << nda.conflicts.num_groups()
    );

    // The S color: both dims of `a` share it.
    let a = ValueId(8);
    assert_eq!(nda.color_of(a, 0), nda.color_of(a, 1), "a:[S,S] conflict");
    let s_color = nda.color_of(a, 0);

    let mesh = Mesh::grid(&[("s", 4)]);
    for order in [0u64, u64::MAX] {
        let assignment = nda.sharding_assignment(s_color, order);
        let mut spec = ShardingSpec::unsharded(&func);
        let ok: Vec<_> = assignment
            .into_iter()
            .filter(|&(v, d)| spec.check(&func, &mesh, v, d, 0).is_ok())
            .collect();
        spec.apply_assignment(&func, &mesh, &ok, 0)?;
        let (local, stats) = partition(&func, &spec, &mesh)?;
        let v = validate_spec(&func, &spec, &mesh, 11)?;
        println!(
            "\nresolution order {}: a sharded as {}",
            if order == 0 { "0" } else { "1" },
            spec.describe_value(&func, &mesh, a),
        );
        println!(
            "  collectives: {} all_gather, {} reduce_scatter, {} all_reduce, {} all_to_all",
            stats.all_gather, stats.reduce_scatter, stats.all_reduce, stats.all_to_all
        );
        println!("  max |Δ| vs unsharded execution: {:.3e}", v.max_abs_diff);
        assert!(v.max_abs_diff < 1e-3);
        let text = format!("{local}");
        let has_seq_pattern = text.contains("all_gather") || text.contains("reduce_scatter");
        println!(
            "  matches Figure 5b sequence-sharding pattern: {}",
            if has_seq_pattern { "yes" } else { "no (other resolution)" }
        );
    }
    println!("\nOK — both conflict resolutions are valid SPMD programs with different comms.");
    Ok(())
}
