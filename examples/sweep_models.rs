//! Mini Figure-8 sweep: partition the paper's evaluation models with all
//! four methods on one platform and print the comparison table.
//!
//! Run: `cargo run --release --example sweep_models`
//! (Use `toast bench --experiment fig8` for the full grid.)

use toast::baselines::Method;
use toast::coordinator::experiments::{format_fig8, format_fig9, run_grid, BenchScale};
use toast::mesh::HardwareKind;
use toast::models::ModelKind;

fn main() {
    let models = [ModelKind::T2B, ModelKind::Gns, ModelKind::Itx];
    println!(
        "sweeping {:?} x {:?} x {:?} (bench scale — structure-preserving shrink)\n",
        models.iter().map(|m| m.name()).collect::<Vec<_>>(),
        ["A100"],
        Method::all().iter().map(|m| m.name()).collect::<Vec<_>>(),
    );
    let rows = run_grid(BenchScale::Bench, &models, &[HardwareKind::A100], &Method::all());
    print!("{}", format_fig8(&rows));
    println!();
    print!("{}", format_fig9(&rows));

    // The paper's headline: TOAST at least matches every baseline.
    for mk in models {
        let toast_row = rows
            .iter()
            .find(|r| r.model == mk && r.method == Method::Toast)
            .expect("toast row");
        for r in rows.iter().filter(|r| r.model == mk && r.method != Method::Toast) {
            if !toast_row.oom && !r.oom {
                let slack = toast_row.step_ms / r.step_ms;
                println!(
                    "{:>6}: TOAST {:>9.3} ms vs {:<8} {:>9.3} ms ({}{:.0}%)",
                    mk.name(),
                    toast_row.step_ms,
                    r.method.name(),
                    r.step_ms,
                    if slack <= 1.0 { "-" } else { "+" },
                    (slack - 1.0).abs() * 100.0
                );
            }
        }
    }
}
