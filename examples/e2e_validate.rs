//! End-to-end differential validation of the SPMD runtime.
//!
//! Exercises the two-executor architecture over the whole scaled model
//! zoo with fixed seeds:
//!
//! 1. **Differential sweep** — every scaled zoo model × four mesh shapes
//!    (two 1-D, one 2-D, one 2-D with a singleton axis) × three sharding
//!    specs (unsharded sanity, a greedy NDA action walk, a seeded random
//!    legal spec). Each triple is partitioned, executed on the SPMD
//!    simulator, and compared to the interpreter oracle; the run fails
//!    if any triple diverges beyond 1e-4 relative error.
//! 2. **Search validation** — validated partitioning sessions
//!    (`.validate(true)`) run on scaled MLP and Transformer, proving the
//!    *winning* spec of a real search is semantics-preserving, not just
//!    hand-picked ones.
//!
//! No artifacts or accelerators are needed — this is the pure-Rust
//! correctness gate CI's `differential` job runs on every push.
//!
//! Run: `cargo run --release --example e2e_validate`

use toast::api::CompiledModel;
use toast::coordinator::experiments::{format_differential, run_differential_suite};
use toast::mesh::Mesh;
use toast::models::ModelKind;
use toast::runtime::diff::DEFAULT_REL_TOL;
use toast::search::ActionSpaceConfig;

fn main() -> anyhow::Result<()> {
    // ---- differential sweep over the scaled zoo ---------------------------
    let models = ModelKind::all();
    println!(
        "differential sweep: {} scaled models x 4 meshes x up to 3 specs (tol {:.1e})",
        models.len(),
        DEFAULT_REL_TOL
    );
    let rows = run_differential_suite(&models, 0xE2E, DEFAULT_REL_TOL);
    print!("{}", format_differential(&rows, DEFAULT_REL_TOL));
    let failed = rows.iter().filter(|r| !r.pass).count();
    anyhow::ensure!(failed == 0, "{failed} differential triples diverged");
    let with_collectives = rows.iter().filter(|r| r.collectives > 0).count();
    anyhow::ensure!(
        with_collectives > 0,
        "sweep exercised no collectives — specs degenerated to replication"
    );
    println!(
        "OK — {} triples agree with the oracle ({} executed real collectives)\n",
        rows.len(),
        with_collectives
    );

    // ---- validated search sessions on MLP and Transformer -----------------
    for (kind, mesh) in [
        (ModelKind::Mlp, Mesh::grid(&[("data", 2), ("model", 2)])),
        (ModelKind::T2B, Mesh::grid(&[("data", 2), ("model", 2)])),
    ] {
        let compiled = CompiledModel::from_kind(kind, false)?;
        let sol = compiled
            .partition(&mesh)
            .action_config(ActionSpaceConfig { min_color_dims: 1, ..Default::default() })
            .budget(150)
            .seed(7)
            .validate(true)
            .run()?;
        let v = sol.validation.as_ref().expect("session ran with validate(true)");
        println!(
            "search {} on {}: relative cost {:.4}, best-spec divergence {:.3e}",
            kind.name(),
            mesh.describe(),
            sol.relative,
            v.max_rel_err
        );
        anyhow::ensure!(
            v.pass,
            "{}: winning spec diverged from the oracle ({:.3e})",
            kind.name(),
            v.max_rel_err
        );
    }
    println!("\nOK — search winners execute correctly on the SPMD runtime");
    Ok(())
}
