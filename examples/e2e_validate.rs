//! End-to-end driver over all three layers (DESIGN.md E7):
//!
//! 1. load the AOT artifacts (`make artifacts`): the L2 JAX transformer —
//!    whose attention runs through the L1 Pallas kernel — lowered to HLO
//!    text and compiled on the PJRT CPU client;
//! 2. train data-parallel across N simulated devices: per-device `grad`
//!    executions, host gradient all-reduce (the L3 collective), `adam`
//!    apply — logging the loss curve;
//! 3. validate that N-device training matches single-device training
//!    numerically (same losses), proving the partitioned execution is
//!    semantics-preserving on the *real* XLA runtime, not just the
//!    in-crate interpreter;
//! 4. report step latency and token throughput per device count.
//!
//! Run: `make artifacts && cargo run --release --example e2e_validate`

use toast::runtime::simexec::DataParallelTrainer;
use toast::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    let rt = Runtime::load_dir(&dir)?;
    let cfg = &rt.manifest.config;
    println!(
        "model: d_model={} layers={} vocab={} batch={} seq={} ({} artifacts)",
        cfg["d_model"], cfg["layers"], cfg["vocab"], cfg["batch"], cfg["seq"],
        rt.artifacts.len()
    );

    // ---- numeric equivalence: 1 device vs 4 devices -----------------------
    let steps = 6;
    let mut t1 = DataParallelTrainer::new(&rt, 1, 42)?;
    let r1 = t1.train(steps, 4)?;
    let mut t4 = DataParallelTrainer::new(&rt, 4, 42)?;
    let r4 = t4.train(steps, 4)?;
    println!("\nloss parity (1 device vs 4 devices, same seed):");
    let mut max_diff = 0.0f32;
    for (s, (a, b)) in r1.losses.iter().zip(&r4.losses).enumerate() {
        println!("  step {s}: {a:.6} vs {b:.6}");
        max_diff = max_diff.max((a - b).abs());
    }
    anyhow::ensure!(max_diff < 1e-3, "data-parallel training diverged: {max_diff}");
    println!("max loss divergence: {max_diff:.2e} — partitioned run is semantics-preserving");

    // ---- the training curve (the E7 headline artifact) --------------------
    let train_steps = 30;
    let mut trainer = DataParallelTrainer::new(&rt, 4, 7)?;
    let report = trainer.train(train_steps, 8)?;
    println!("\ntraining {} steps on 4 simulated devices:", train_steps);
    for (s, l) in report.losses.iter().enumerate() {
        if s % 5 == 0 || s == train_steps - 1 {
            println!("  step {s:>3}: loss {l:.4}");
        }
    }
    let k = (train_steps / 4).max(1);
    let head: f32 = report.losses[..k].iter().sum::<f32>() / k as f32;
    let tail: f32 =
        report.losses[report.losses.len() - k..].iter().sum::<f32>() / k as f32;
    anyhow::ensure!(tail < head, "loss must decrease ({head:.4} -> {tail:.4})");

    // ---- throughput scaling ------------------------------------------------
    println!("\nthroughput (tokens/s) by simulated device count:");
    for devices in [1usize, 2, 4] {
        let mut t = DataParallelTrainer::new(&rt, devices, 3)?;
        let r = t.train(5, 2)?;
        println!(
            "  {} device(s): {:>8.1} ms/step, {:>9.0} tokens/s",
            devices,
            r.mean_step_ms(),
            r.throughput_tokens_per_s()
        );
    }
    println!("\nOK — three-layer stack (Pallas kernel → JAX model → Rust PJRT coordinator) composes.");
    Ok(())
}
