//! End-to-end differential validation of the SPMD runtime.
//!
//! Exercises the two-executor architecture over the whole scaled model
//! zoo with fixed seeds:
//!
//! 1. **Differential sweep** — every scaled zoo model × four mesh shapes
//!    (two 1-D, one 2-D, one 2-D with a singleton axis) × three sharding
//!    specs (unsharded sanity, a greedy NDA action walk, a seeded random
//!    legal spec). Each triple is partitioned, executed on the SPMD
//!    simulator, and compared to the interpreter oracle; the run fails
//!    if any triple diverges beyond 1e-4 relative error.
//! 2. **Search validation** — the MCTS auto-partitioner runs on scaled
//!    MLP and Transformer with `validate_best` set, proving the
//!    *winning* spec of a real search is semantics-preserving, not just
//!    hand-picked ones.
//!
//! No artifacts or accelerators are needed — this is the pure-Rust
//! correctness gate CI's `differential` job runs on every push.
//!
//! Run: `cargo run --release --example e2e_validate`

use toast::coordinator::experiments::{format_differential, run_differential_suite};
use toast::cost::CostModel;
use toast::mesh::{HardwareKind, HardwareProfile, Mesh};
use toast::models::ModelKind;
use toast::runtime::diff::DEFAULT_REL_TOL;
use toast::search::{auto_partition, ActionSpaceConfig, SearchConfig};

fn main() -> anyhow::Result<()> {
    // ---- differential sweep over the scaled zoo ---------------------------
    let models = ModelKind::all();
    println!(
        "differential sweep: {} scaled models x 4 meshes x up to 3 specs (tol {:.1e})",
        models.len(),
        DEFAULT_REL_TOL
    );
    let rows = run_differential_suite(&models, 0xE2E, DEFAULT_REL_TOL);
    print!("{}", format_differential(&rows, DEFAULT_REL_TOL));
    let failed = rows.iter().filter(|r| !r.pass).count();
    anyhow::ensure!(failed == 0, "{failed} differential triples diverged");
    let with_collectives = rows.iter().filter(|r| r.collectives > 0).count();
    anyhow::ensure!(
        with_collectives > 0,
        "sweep exercised no collectives — specs degenerated to replication"
    );
    println!(
        "OK — {} triples agree with the oracle ({} executed real collectives)\n",
        rows.len(),
        with_collectives
    );

    // ---- search --validate-best on MLP and Transformer --------------------
    let model = CostModel::new(HardwareProfile::new(HardwareKind::A100));
    for (kind, mesh) in [
        (ModelKind::Mlp, Mesh::grid(&[("data", 2), ("model", 2)])),
        (ModelKind::T2B, Mesh::grid(&[("data", 2), ("model", 2)])),
    ] {
        let func = kind.build_scaled();
        let out = auto_partition(
            &func,
            &mesh,
            &model,
            &ActionSpaceConfig { min_color_dims: 1, ..Default::default() },
            &SearchConfig { budget: 150, seed: 7, validate_best: true, ..Default::default() },
        );
        let v = out.validation.expect("validate_best was set");
        println!(
            "search {} on {}: relative cost {:.4}, {} actions, best-spec divergence {:.3e}",
            kind.name(),
            mesh.describe(),
            out.relative,
            out.actions.len(),
            v
        );
        anyhow::ensure!(
            v <= DEFAULT_REL_TOL as f64,
            "{}: winning spec diverged from the oracle ({v:.3e})",
            kind.name()
        );
    }
    println!("\nOK — search winners execute correctly on the SPMD runtime");
    Ok(())
}
